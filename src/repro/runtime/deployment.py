"""``DeploymentSpec``: one hardware-aware deployment API.

The paper's provisioning argument (HBM-CO §III, Fig 9/10; bandwidth-first
chiplet provisioning §IV) is that a serving deployment is fully determined
by a *hardware point* — memory capacity, memory bandwidth, energy/bit —
plus the model's byte footprint.  Until now the analytic side
(``core.{hbmco,sku,roofline,provisioning}``) and the serving runtime
(``runtime.{engine,llm,kv_cache,scheduler}``) computed with the same
quantities but never met: engines sized their paged KV pool from a
hand-tuned ``num_pages`` knob.

``DeploymentSpec`` is the seam.  It names a hardware point (a device SKU
and/or an HBM-CO stack), a mesh shape, and the weight/cache number
formats, and ``resolve()`` turns that into the runtime configuration:

  **memory budget** (per device)
      capacity  =  weights  +  workspace  +  KV pool
      ─ weights: total params x bits/weight (``quant.formats`` block
        formats — the RPU streams compressed weights through the Stream
        Decoder, §V), per-device under TP via the serve plan's partition
        specs (KV-replicated ``wk``/``wv`` count their replicas);
      ─ workspace: a configurable fraction reserved for activations,
        logits, and allocator metadata;
      ─ KV pool: whatever capacity remains sizes ``num_pages``
        (page bytes shrink 1/TP for sharded pool leaves).

  **bandwidth model** (memory roofline — decode is bandwidth-bound, §II)
      step_seconds(b) = (weight stream + b x KV-context stream) / BW
      The knee ``b* ~ weight_bytes / kv_context_bytes`` — the batch where
      the KV stream equals the weight stream and per-token latency has
      doubled — bounds ``num_slots`` and is surfaced as the scheduler's
      ``max_decode_slots`` admission hint; ``tokens_per_s_ceiling`` is the
      modeled throughput the capacity-sweep benchmark compares real runs
      against.

Every front-end consumes the same object::

    spec = DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                          weight_format="mxfp4", max_len=4096)
    llm = LLMEngine(model, params, spec=spec)      # pools sized from spec
    print(llm.deployment.describe())

so a new SKU, HBM-CO stack, or quantized cache is a config change, not an
engine change.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hardware
from repro.core.hbmco import CANDIDATE_CO, HBMCOConfig, hbmco_by_name
from repro.models.footprint import compute_footprint
from repro.quant import formats
from repro.quant import kv as kvq


class DeploymentError(ValueError):
    """The spec's hardware point cannot back the requested deployment."""


@dataclasses.dataclass(frozen=True)
class DeviceBudget:
    """The per-device hardware point a spec resolves against."""

    name: str
    capacity_bytes: float          # usable HBM per device
    decode_bw: float               # bytes/s sustained during decode
    energy_pj_per_bit: float | None = None   # memory-stream energy, if known


# Named compute SKUs (``core.hardware``).  "rpu-cu" is one RPU compute
# unit: 2 HBM-CO chiplets on dual 256 GB/s shorelines (paper §IV).
CHIP_SKUS = {
    "tpu-v5e": hardware.TPU_V5E,
    "tpu_v5e": hardware.TPU_V5E,
    "h100": hardware.H100,
    "h200": hardware.H200,
}


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """One hardware-aware deployment configuration.

    sku            "rpu-cu", a name from ``CHIP_SKUS``, or a ``ChipSpec``.
    hbmco          HBM-CO stack (config or name — see ``hbmco_by_name``).
                   When set, the memory system is ``stacks_per_device``
                   such stacks (capacity/bandwidth/energy from the §III
                   model); required for ``sku="rpu-cu"`` (defaults to the
                   paper's 768 MB candidate).  When None, the SKU's native
                   HBM numbers apply (GPU decode bandwidth derated by the
                   paper's measured §II utilization).
    mesh           ``jax.sharding.Mesh`` | ``"DxM"`` | ``(D, M)`` | None.
    weight_format  ``quant.formats`` name ("mxfp4", ...) for the weight
                   budget; None = native parameter dtype.
    cache_dtype    KV-pool dtype (None = engine default bf16).
    max_len        per-request token capacity (prompt + generated).
    page_size      KV page tokens.
    prefill_chunk  admission chunk tokens (None = derived from the SKU's
                   FLOPs knee: the chunk where compute time crosses the
                   weight-stream time, page-rounded and clamped to
                   [page_size, min(512, max_len)]).
    max_slots      upper bound on the derived slot count.
    overcommit     capacity admission optimism: slots may cover
                   ``overcommit x`` the pool's worst-case token capacity
                   (restart-style preemption is the backstop — >1 trades
                   preemption risk for occupancy, the Fig-10 trade-off).
    mean_context   expected live context per slot for the bandwidth model
                   (None = ``max_len // 2``).
    workspace_fraction  capacity reserved for activations + allocator
                   metadata before the KV pool is sized.
    """

    sku: str | hardware.ChipSpec = "rpu-cu"
    hbmco: str | HBMCOConfig | None = None
    mesh: Any = None
    tp_reduce: str = "auto"
    weight_format: str | None = None
    cache_dtype: Any = None
    max_len: int = 256
    page_size: int = 16
    prefill_chunk: int | None = None
    max_slots: int = 32
    overcommit: float = 1.0
    mean_context: int | None = None
    workspace_fraction: float = 0.05
    stacks_per_device: int = 2

    def __post_init__(self):
        if self.max_len < 1 or self.page_size < 1:
            raise ValueError("max_len and page_size must be >= 1")
        if self.max_slots < 1:
            raise ValueError(f"max_slots={self.max_slots} must be >= 1")
        if self.overcommit <= 0.0:
            raise ValueError(f"overcommit={self.overcommit} must be > 0")
        if not 0.0 <= self.workspace_fraction < 1.0:
            raise ValueError("workspace_fraction must be in [0, 1)")
        if self.weight_format is not None \
                and self.weight_format not in formats.FORMATS:
            raise ValueError(f"unknown weight_format {self.weight_format!r}; "
                             f"known: {sorted(formats.FORMATS)}")
        kvq.validate_cache_dtype(self.cache_dtype)   # "fp8"/"int8" strings

    # ---------------- hardware point ----------------
    def device_budget(self) -> DeviceBudget:
        """Resolve (sku, hbmco) into per-device capacity/BW/energy."""
        hbm = self.hbmco
        if isinstance(hbm, str):
            hbm = hbmco_by_name(hbm)
        if isinstance(self.sku, str) and self.sku == "rpu-cu":
            hbm = hbm or CANDIDATE_CO
            rpu = hardware.RPU_DEFAULT
            n = self.stacks_per_device
            return DeviceBudget(
                name=f"rpu-cu[{n}x{hbm.name}]",
                capacity_bytes=n * hbm.capacity_bytes,
                decode_bw=min(rpu.cu_mem_bw, n * hbm.bandwidth_gbs * 1e9),
                energy_pj_per_bit=hbm.energy_pj_per_bit)
        chip = self.sku if isinstance(self.sku, hardware.ChipSpec) \
            else CHIP_SKUS.get(self.sku)
        if chip is None:
            raise ValueError(f"unknown sku {self.sku!r}; known: 'rpu-cu', "
                             f"{sorted(set(CHIP_SKUS) - {'tpu_v5e'})}")
        if hbm is not None:        # HBM-CO retrofit of a named chip
            n = self.stacks_per_device
            return DeviceBudget(
                name=f"{chip.name}[{n}x{hbm.name}]",
                capacity_bytes=n * hbm.capacity_bytes,
                decode_bw=min(chip.hbm_bw, n * hbm.bandwidth_gbs * 1e9),
                energy_pj_per_bit=hbm.energy_pj_per_bit)
        bw = chip.hbm_bw
        if isinstance(chip, hardware.GPUSpec):
            bw *= chip.decode_bw_utilization     # paper §II: 32% on H100
        return DeviceBudget(name=chip.name, capacity_bytes=chip.hbm_capacity,
                            decode_bw=bw)

    def _device_compute(self) -> tuple[float, float]:
        """(effective prefill FLOP/s, weight-stream bytes/s) per device —
        the compute roofline prefill chunks run against.  The decode
        bandwidth derate does NOT apply here: a prefill chunk streams the
        weights once at full sequential bandwidth.  RPU CUs provision
        compute at ``ops_per_byte`` x their memory bandwidth (paper §IV),
        so their prefill roofline is weak by design — decode is the phase
        they are priced for."""
        hbm = self.hbmco
        if isinstance(hbm, str):
            hbm = hbmco_by_name(hbm)
        if isinstance(self.sku, str) and self.sku == "rpu-cu":
            hbm = hbm or CANDIDATE_CO
            rpu = hardware.RPU_DEFAULT
            bw = min(rpu.cu_mem_bw,
                     self.stacks_per_device * hbm.bandwidth_gbs * 1e9)
            return rpu.cu_tops, bw
        chip = self.sku if isinstance(self.sku, hardware.ChipSpec) \
            else CHIP_SKUS[self.sku]
        bw = chip.hbm_bw
        if hbm is not None:
            bw = min(chip.hbm_bw,
                     self.stacks_per_device * hbm.bandwidth_gbs * 1e9)
        eff = getattr(chip, "compute_efficiency", 0.7)
        return chip.peak_flops_bf16 * eff, bw

    def _resolve_mesh(self, override=None):
        mesh = override if override is not None else self.mesh
        if mesh is None or isinstance(mesh, jax.sharding.Mesh):
            return mesh
        if isinstance(mesh, str):
            try:
                d, m = (int(x) for x in mesh.lower().split("x"))
            except ValueError:
                raise ValueError(f"mesh spec wants 'DxM', got {mesh!r}") \
                    from None
            return jax.make_mesh((d, m), ("data", "model"))
        d, m = mesh
        return jax.make_mesh((int(d), int(m)), ("data", "model"))

    # ---------------- resolution ----------------
    def resolve(self, model, params=None, mesh=None, *, draft=None,
                draft_params=None, gamma: int = 8,
                spec_accept_rate: float = 0.7,
                phase: str = "colocated") -> "ResolvedDeployment":
        """Turn the spec into runtime numbers for ``model``.

        ``params`` makes the weight budget exact (per-leaf bytes through
        the serve plan's partition specs); without it the footprint
        estimate is used.  ``mesh`` overrides the spec's mesh.

        ``phase`` prices the deployment for one side of a disaggregated
        split: "prefill" budgets slots/pages for chunked prompt compute
        (the compute roofline — ``step_seconds`` becomes the batched
        chunk iteration time and the ceiling counts PROMPT tokens/s),
        "decode" is the bandwidth-roofline point with no prefill
        interference (the colocated numbers, tagged), and "colocated"
        (default) is the single-engine budget.

        ``draft`` prices a speculative deployment: the draft's weights
        join the capacity budget, every logical KV page carries BOTH
        models' pool bytes (the draft's pages come out of the same
        allocator), and the bandwidth model becomes per-WINDOW — gamma
        draft steps (draft weight + draft KV stream) plus one verify step
        (the target's decode stream: a q_len = gamma+1 verify reads the
        same weight/KV bytes as a single decode step, the extra FLOPs are
        free in a bandwidth-bound regime).  ``spec_accept_rate`` is the
        modeled per-token acceptance probability alpha; a window emits
        ``alpha(1-alpha^gamma)/(1-alpha) + 1`` expected tokens."""
        from repro.parallel.plan import make_paged_serve_plan, \
            paged_kv_token_bytes, paged_kv_token_bytes_split
        from repro.runtime.state_cache import model_cache_layout, \
            ring_pages_needed, state_bytes_per_slot

        if phase not in ("colocated", "prefill", "decode"):
            raise ValueError(f"phase={phase!r}: expected 'colocated', "
                             f"'prefill', or 'decode'")
        cfg = model.cfg
        # Stateful cache layouts (sliding-window ring pages, SSM state
        # pools — runtime/state_cache.py) change what a slot keeps
        # resident; combinations the runtime cannot serve are rejected
        # here with a deployment-level error, mirroring the MLA+quantized
        # treatment below, instead of failing layers deep in the engine.
        lay = model_cache_layout(model.plan)
        dlay = model_cache_layout(draft.plan) if draft is not None else None
        if draft is not None and (lay.stateful or dlay.stateful):
            role, c = ("model", cfg) if lay.stateful else ("draft", draft.cfg)
            raise DeploymentError(
                f"speculative decoding is unsupported for the "
                f"stateful-cache {role} {c.name!r}: draft/verify rewinds "
                f"token-indexed KV pages on rejection, but recurrent SSM "
                f"state and reclaimed ring pages cannot rewind. Serve "
                f"this architecture without a draft (state rewind is a "
                f"recorded follow-on).")
        if lay.has_state and kvq.is_quantized_cache_dtype(self.cache_dtype):
            raise DeploymentError(
                f"cache_dtype={self.cache_dtype!r} is unsupported for the "
                f"state-carrying model {cfg.name!r}: SSM state pools stay "
                f"bf16 (conv tail) / f32 (SSD state) — quantized state "
                f"pools are a recorded follow-on. Use cache_dtype=None "
                f"(bf16) or jnp.float32 for this architecture.")
        if lay.stateful and phase != "colocated":
            raise DeploymentError(
                f"phase={phase!r} is unsupported for the stateful-cache "
                f"model {cfg.name!r}: the disaggregated KV handoff moves "
                f"full-space page chains only — recurrent SSM state and "
                f"ring residency need their own transfer (recorded "
                f"follow-on). Use phase='colocated'.")
        # Reject MLA + quantized KV up front with a deployment-level error
        # instead of letting pool construction explode layers deep inside
        # paged_kv_token_bytes: latent pages have no dequant seam yet.
        if kvq.is_quantized_cache_dtype(self.cache_dtype):
            for role, c in [("model", cfg)] + \
                    ([("draft", draft.cfg)] if draft is not None else []):
                if getattr(c, "mla", False):
                    raise DeploymentError(
                        f"cache_dtype={self.cache_dtype!r} is unsupported "
                        f"for the MLA {role} {c.name!r}: quantized KV "
                        f"({'/'.join(sorted(kvq.KV_FORMATS))}) exists only "
                        f"for GQA page pools — MLA latent pages stay dense. "
                        f"Use cache_dtype=None (bf16) or jnp.float32 for "
                        f"this architecture.")
        mesh = self._resolve_mesh(mesh)
        plan = None
        tp = kv_repl = 1
        if mesh is not None:
            plan = make_paged_serve_plan(cfg, mesh, reduce=self.tp_reduce)
            tp, kv_repl = plan.tp, plan.kv_repl
        dev = self.device_budget()
        fp = compute_footprint(cfg)
        wbits = (formats.bits_per_element(self.weight_format)
                 if self.weight_format else None)
        per = (wbits / 8.0) if wbits else 2.0              # bf16 default

        # -- weights, per device --
        if params is not None:
            weight_bytes = self._weight_bytes_exact(params, plan, tp,
                                                    kv_repl)
        else:
            # no params: a conservative estimate — treat every weight as
            # replicated.  Dividing by tp here would need the per-leaf
            # partition specs (MoE experts, norms, and embeddings stay
            # replicated in the serve plan, and KV-replicated wk/wv keep
            # kv_repl copies); overstating weights only shrinks the KV
            # pool, never passes an infeasible deployment.
            weight_bytes = fp.total_params * per

        # -- speculative draft: weights + per-page pool bytes --
        cache_dtype = self.cache_dtype if self.cache_dtype is not None \
            else jnp.bfloat16
        draft_weight_bytes = 0.0
        draft_kv_token = 0
        dfp = dplan = None
        dtp = 1
        if draft is not None:
            dfp = compute_footprint(draft.cfg)
            dkv_repl = 1
            if mesh is not None:
                dplan = make_paged_serve_plan(draft.cfg, mesh,
                                              reduce=self.tp_reduce)
                dtp, dkv_repl = dplan.tp, dplan.kv_repl
            if draft_params is not None:
                draft_weight_bytes = self._weight_bytes_exact(
                    draft_params, dplan, dtp, dkv_repl)
            else:
                draft_weight_bytes = dfp.total_params * per
            draft_kv_token = paged_kv_token_bytes(
                draft, tp=dtp, kv_repl=dkv_repl, cache_dtype=cache_dtype)
            weight_bytes += draft_weight_bytes

        # -- workspace + KV budget --
        workspace = self.workspace_fraction * dev.capacity_bytes
        kv_budget = dev.capacity_bytes - weight_bytes - workspace
        # measured from an actual tiny pool at this dtype, so quantized
        # fp8/int8 pools price codes + scale metadata — the bytes the
        # engine allocates, not a nominal itemsize.  With a draft, every
        # logical page costs both pool sets.  The split prices the two
        # token-indexed residency classes separately: full-context
        # segments hold O(max_len) per slot, sliding-window segments only
        # O(window) once the ring space reclaims pages behind the window.
        kv_full, kv_ring = paged_kv_token_bytes_split(
            model, tp=tp, kv_repl=kv_repl, cache_dtype=cache_dtype)
        kv_full += draft_kv_token      # draft pages live in the full space
        kv_token = kv_full + kv_ring
        max_blocks = -(-self.max_len // self.page_size)

        # -- bandwidth-model inputs --
        per_w = (wbits / 8.0) if wbits else 2.0
        active_bytes = fp.active_params * per_w / tp
        ctx = self.mean_context if self.mean_context is not None \
            else max(self.max_len // 2, 1)

        # -- compute roofline: prefill chunk from the SKU's FLOPs knee --
        # A chunk of C tokens costs ~2 x active_params x C FLOPs against
        # one weight stream; the knee C* = F_eff x bytes/weight / (2 x BW)
        # is where chunk compute time crosses the weight-stream time —
        # smaller chunks waste bandwidth re-streaming weights, larger ones
        # only add TTFT.  Rounded to whole pages, clamped to
        # [page_size, min(512, max_len)]; an explicit prefill_chunk wins.
        # (Derived before the capacity math: the ring space's transient
        # residency bound depends on the chunk width.)
        flops_eff, stream_bw = self._device_compute()
        chunk_knee = flops_eff * per_w / (2.0 * stream_bw)
        chunk_derived = self.prefill_chunk is None
        if chunk_derived:
            prefill_chunk = round(chunk_knee / self.page_size) \
                * self.page_size
            prefill_chunk = max(self.page_size,
                                min(prefill_chunk, 512, self.max_len))
        else:
            prefill_chunk = self.prefill_chunk

        # -- capacity -> slots/pages --
        if not lay.stateful:
            page_bytes = kv_token * self.page_size
            if kv_budget < page_bytes * max_blocks:
                raise DeploymentError(
                    f"{dev.name}: {_fmt_bytes(dev.capacity_bytes)} capacity "
                    f"leaves {_fmt_bytes(max(kv_budget, 0))} for KV after "
                    f"{_fmt_bytes(weight_bytes)} weights + "
                    f"{_fmt_bytes(workspace)} workspace — cannot back one "
                    f"max_len={self.max_len} request "
                    f"({max_blocks} pages x {_fmt_bytes(page_bytes)}); pick "
                    "a larger-capacity SKU, quantize "
                    "(weight_format/cache_dtype), or lower max_len")
            budget_pages = int(kv_budget // page_bytes)
            budget_tokens = budget_pages * self.page_size
            kv_ctx = max(kv_token * ctx, 1.0)
            knee = max(1, round(active_bytes / kv_ctx))
            slots_cap = max(1, int(budget_tokens * self.overcommit
                                   // self.max_len))
            num_slots = max(1, min(knee, slots_cap, self.max_slots))
            max_decode_slots = max(1, min(knee, self.max_slots))
            # the pool never needs more pages than a fully-occupied slot
            # set plus prefix-cache slack (caps host allocation on huge
            # SKUs)
            num_pages = 1 + min(budget_pages, 4 * num_slots * max_blocks)
            num_ring_pages = 0
            state_b = 0
        else:
            # Per-family residency: a slot's worst case holds max_blocks
            # full pages + the ring's transient bound + its state entry,
            # and its decode stream reads O(window) ring tokens rather
            # than O(context).
            state_b = state_bytes_per_slot(cfg) if lay.has_state else 0
            ring_w = lay.ring_window or 0
            ring_cap = min(max_blocks,
                           -(-(ring_w + prefill_chunk) // self.page_size)
                           + 1) if lay.has_ring else 0
            slot_resident = (kv_full * self.page_size * max_blocks
                             + kv_ring * self.page_size * ring_cap
                             + state_b)
            if kv_budget < slot_resident:
                raise DeploymentError(
                    f"{dev.name}: {_fmt_bytes(dev.capacity_bytes)} capacity "
                    f"leaves {_fmt_bytes(max(kv_budget, 0))} for the cache "
                    f"after {_fmt_bytes(weight_bytes)} weights + "
                    f"{_fmt_bytes(workspace)} workspace — cannot back one "
                    f"max_len={self.max_len} slot of {cfg.name!r} "
                    f"({_fmt_bytes(slot_resident)} resident: full pages + "
                    f"ring window + state); pick a larger-capacity SKU, "
                    "quantize the weights, or lower max_len")
            kv_ctx = max(kv_full * ctx + kv_ring * min(ctx, ring_w)
                         + state_b, 1.0)
            knee = max(1, round(active_bytes / kv_ctx))
            slots_cap = max(1, int(kv_budget * self.overcommit
                                   // slot_resident))
            num_slots = max(1, min(knee, slots_cap, self.max_slots))
            max_decode_slots = max(1, min(knee, self.max_slots))
            num_ring_pages = ring_pages_needed(
                num_slots=num_slots, window=ring_w,
                page_size=self.page_size, max_blocks=max_blocks,
                prefill_chunk=prefill_chunk) if lay.has_ring else 0
            ring_pool = max(num_ring_pages - 1, 0) * kv_ring \
                * self.page_size
            rem = kv_budget - num_slots * state_b - ring_pool
            if lay.has_full:
                fpage = kv_full * self.page_size
                budget_pages = int(max(rem, 0.0) // fpage)
                if budget_pages < max_blocks:
                    raise DeploymentError(
                        f"{dev.name}: state pools "
                        f"({num_slots} x {_fmt_bytes(state_b)}) + ring "
                        f"space ({_fmt_bytes(ring_pool)}) leave "
                        f"{_fmt_bytes(max(rem, 0.0))} for full-context KV "
                        f"— cannot back one max_len={self.max_len} "
                        f"request of {cfg.name!r}; pick a larger-capacity "
                        "SKU or lower max_len")
                budget_tokens = budget_pages * self.page_size
                num_pages = 1 + min(budget_pages,
                                    4 * num_slots * max_blocks)
            else:
                # no full-context layers: the full space never allocates
                # a page, but the engine still sizes its (empty) pool
                # table for max_blocks
                budget_pages = 0
                budget_tokens = slots_cap * self.max_len
                num_pages = 1 + max_blocks

        step_s = (active_bytes + num_slots * kv_ctx) / dev.decode_bw
        ceiling = num_slots / step_s
        if phase == "prefill":
            # compute-phase budget: enough concurrent chunks to cover the
            # weight stream at the chosen width (+1 for admission overlap);
            # the iteration time is the max of batched chunk compute and
            # one weight stream, and the ceiling counts PROMPT tokens/s
            num_slots = max(1, min(slots_cap, self.max_slots,
                                   int(math.ceil(chunk_knee / prefill_chunk))
                                   + 1))
            num_pages = 1 + min(budget_pages, 4 * num_slots * max_blocks)
            tokens = num_slots * prefill_chunk
            compute_s = 2.0 * fp.active_params * tokens / (flops_eff * tp)
            step_s = max(compute_s, active_bytes / stream_bw)
            ceiling = tokens / step_s
        j_per_tok = None
        if dev.energy_pj_per_bit is not None:
            stream = (active_bytes + num_slots * kv_ctx) * tp
            j_per_tok = stream * 8.0 * dev.energy_pj_per_bit * 1e-12 \
                / num_slots

        # -- speculative window model --
        spec_kwargs = {}
        if draft is not None:
            g = int(gamma)
            a = min(max(float(spec_accept_rate), 0.0), 1.0)
            draft_active = dfp.active_params * per / dtp
            draft_kv_ctx = max(draft_kv_token * ctx, 1.0)
            draft_step_s = (draft_active + num_slots * draft_kv_ctx) \
                / dev.decode_bw
            window_s = g * draft_step_s + step_s
            expected = float(g) if a >= 1.0 \
                else a * (1.0 - a ** g) / (1.0 - a)
            spec_kwargs = dict(
                draft_weight_bytes_per_device=draft_weight_bytes,
                draft_kv_token_bytes=draft_kv_token,
                spec_gamma=g, spec_accept_rate=a,
                spec_expected_accepted=expected,
                spec_window_seconds=window_s,
                spec_tokens_per_s_ceiling=(num_slots * (expected + 1.0)
                                           / window_s))

        return ResolvedDeployment(
            **spec_kwargs,
            spec=self, device=dev, mesh=mesh, tp=tp, kv_repl=kv_repl,
            tp_reduce=self.tp_reduce, cache_dtype=cache_dtype,
            weight_bytes_per_device=weight_bytes,
            workspace_bytes=workspace,
            kv_budget_bytes=kv_budget,
            kv_token_bytes=kv_token,
            ring_token_bytes=kv_ring,
            ring_window=lay.ring_window,
            num_ring_pages=num_ring_pages,
            state_bytes_per_slot=state_b,
            budget_tokens=budget_tokens,
            max_len=self.max_len, page_size=self.page_size,
            prefill_chunk=prefill_chunk,
            num_pages=num_pages, num_slots=num_slots,
            max_decode_slots=max_decode_slots,
            mean_context=ctx,
            step_seconds=step_s,
            tokens_per_s_ceiling=ceiling,
            modeled_j_per_token=j_per_tok,
            phase=phase,
            chunk_knee_tokens=chunk_knee,
            prefill_chunk_derived=chunk_derived,
            prefill_flops=flops_eff,
            stream_bw=stream_bw)

    def _weight_bytes_exact(self, params, plan, tp: int,
                            kv_repl: int) -> float:
        """Per-device weight bytes as the engine will actually allocate
        them: quantizable projection leaves price at their exact packed
        (codes + scales) bytes for ``weight_format``; every other leaf —
        norms, biases, embeddings, MoE/SSM subtrees — keeps its native
        dtype, exactly mirroring ``quant.linear.quantize_params`` /
        ``serve_weight_bytes``, so budget == execution."""
        from repro.parallel.plan import _path_names
        from repro.quant.linear import quantizable_leaf

        fmt = self.weight_format

        def leaf_bytes(path, leaf):
            if fmt is not None and quantizable_leaf(path, leaf, fmt):
                b = float(formats.packed_nbytes(leaf.shape, fmt))
            else:
                b = leaf.size * leaf.dtype.itemsize
            if plan is not None and tp > 1:
                names = _path_names(path)
                spec = plan._serve_param_spec(names, leaf.ndim)
                if any(s is not None for s in spec):
                    repl = kv_repl if names[-1] in ("wk", "wv", "bk", "bv") \
                        else 1
                    b = b * repl / tp
            return b

        return sum(jax.tree.leaves(
            jax.tree_util.tree_map_with_path(leaf_bytes, params)))


@dataclasses.dataclass(frozen=True)
class ResolvedDeployment:
    """A ``DeploymentSpec`` resolved against one model: the engine
    configuration plus the modeled roofline the benchmark compares real
    runs against."""

    spec: DeploymentSpec
    device: DeviceBudget
    mesh: Any
    tp: int
    kv_repl: int
    tp_reduce: str
    cache_dtype: Any
    # memory budget (per device)
    weight_bytes_per_device: float
    workspace_bytes: float
    kv_budget_bytes: float
    kv_token_bytes: int
    budget_tokens: int
    # engine configuration
    max_len: int
    page_size: int
    prefill_chunk: int
    num_pages: int
    num_slots: int
    max_decode_slots: int
    # bandwidth model
    mean_context: int
    step_seconds: float
    tokens_per_s_ceiling: float
    modeled_j_per_token: float | None = None
    # speculative decoding (resolve(draft=...); None when not speculative)
    draft_weight_bytes_per_device: float | None = None
    draft_kv_token_bytes: int | None = None
    spec_gamma: int | None = None
    spec_accept_rate: float | None = None
    spec_expected_accepted: float | None = None   # per window, modeled
    spec_window_seconds: float | None = None      # gamma drafts + 1 verify
    spec_tokens_per_s_ceiling: float | None = None
    # phase-split deployments (resolve(phase=...))
    phase: str = "colocated"
    chunk_knee_tokens: float | None = None   # FLOPs-knee chunk, unclamped
    prefill_chunk_derived: bool = False      # chunk came from the knee
    prefill_flops: float | None = None       # effective FLOP/s per device
    stream_bw: float | None = None           # full weight-stream bytes/s
    # stateful cache layouts (runtime/state_cache.py); all zero/None for
    # the classic all-full-KV layout
    ring_token_bytes: int = 0       # bytes/token in sliding-window layers
    ring_window: int | None = None
    num_ring_pages: int = 0         # ring space incl. scratch (0 = none)
    state_bytes_per_slot: int = 0   # SSM state pool bytes per slot

    @property
    def pool_bytes_per_device(self) -> int:
        """Exactly the bytes the engine's pools allocate: full-space
        pages (scratch excluded) + ring-space pages + state pools."""
        full_tok = self.kv_token_bytes - self.ring_token_bytes
        return ((self.num_pages - 1) * full_tok * self.page_size
                + max(self.num_ring_pages - 1, 0) * self.ring_token_bytes
                * self.page_size
                + self.num_slots * self.state_bytes_per_slot)

    def describe(self) -> str:
        d = self.device
        lines = [
            f"deployment: {d.name}"
            + (f" [{self.phase}]" if self.phase != "colocated" else "")
            + (f" x tp={self.tp}" + (f" (kv_repl={self.kv_repl})"
                                     if self.kv_repl > 1 else "")
               if self.tp > 1 else ""),
            f"  capacity  {_fmt_bytes(d.capacity_bytes):>10}/device = "
            f"{_fmt_bytes(self.weight_bytes_per_device)} weights + "
            f"{_fmt_bytes(self.workspace_bytes)} workspace + "
            f"{_fmt_bytes(self.kv_budget_bytes)} KV budget",
            f"  KV pool   {self.num_pages} pages x {self.page_size} tok x "
            f"{_fmt_bytes(self.kv_token_bytes)}/tok = "
            f"{_fmt_bytes(self.pool_bytes_per_device)}/device",
            *([f"  stateful  ring {max(self.num_ring_pages - 1, 0)} pages "
               f"x {_fmt_bytes(self.ring_token_bytes * self.page_size)} "
               f"(window {self.ring_window}) + state "
               f"{_fmt_bytes(self.state_bytes_per_slot)}/slot x "
               f"{self.num_slots}"]
              if self.num_ring_pages or self.state_bytes_per_slot else []),
            f"  slots     {self.num_slots} "
            f"(admission hint {self.max_decode_slots}; "
            f"{self.budget_tokens} budget tokens, max_len {self.max_len})",
            f"  roofline  {_fmt_bytes(d.decode_bw)}/s -> "
            f"{self.tokens_per_s_ceiling:.1f} tok/s ceiling at "
            f"ctx {self.mean_context} "
            f"({self.step_seconds * 1e3:.2f} ms/step)",
        ]
        if self.prefill_chunk_derived and self.chunk_knee_tokens is not None:
            lines.append(
                f"  chunk     {self.prefill_chunk} tok from the FLOPs knee "
                f"({self.prefill_flops / 1e12:.1f} TFLOP/s x "
                f"{self.spec.weight_format or 'bf16'} weights / "
                f"2 x {_fmt_bytes(self.stream_bw)}/s = "
                f"{self.chunk_knee_tokens:.0f} tok, page-rounded)")
        else:
            lines.append(f"  chunk     {self.prefill_chunk} tok (explicit)")
        if self.modeled_j_per_token is not None:
            lines.append(f"  energy    "
                         f"{self.modeled_j_per_token * 1e3:.3f} mJ/token "
                         f"({d.energy_pj_per_bit:.2f} pJ/bit memory)")
        if self.spec_gamma is not None:
            lines.append(
                f"  spec      gamma={self.spec_gamma} "
                f"(+{_fmt_bytes(self.draft_weight_bytes_per_device)} draft "
                f"weights, +{_fmt_bytes(self.draft_kv_token_bytes)}/tok "
                f"draft KV) -> {self.spec_expected_accepted:.2f} accepted "
                f"per window at alpha={self.spec_accept_rate:.2f}, "
                f"{self.spec_tokens_per_s_ceiling:.1f} tok/s ceiling "
                f"({self.spec_window_seconds * 1e3:.2f} ms/window)")
        return "\n".join(lines)

    def as_dict(self) -> dict:
        """JSON-friendly summary (the capacity-sweep artifact rows)."""
        return {
            "device": self.device.name,
            "capacity_bytes": self.device.capacity_bytes,
            "decode_bw": self.device.decode_bw,
            "tp": self.tp, "kv_repl": self.kv_repl,
            "weight_bytes_per_device": self.weight_bytes_per_device,
            "workspace_bytes": self.workspace_bytes,
            "kv_budget_bytes": self.kv_budget_bytes,
            "kv_token_bytes": self.kv_token_bytes,
            "budget_tokens": self.budget_tokens,
            "num_pages": self.num_pages, "num_slots": self.num_slots,
            "max_decode_slots": self.max_decode_slots,
            "page_size": self.page_size, "max_len": self.max_len,
            "prefill_chunk": self.prefill_chunk,
            "tokens_per_s_ceiling": self.tokens_per_s_ceiling,
            "step_seconds": self.step_seconds,
            "modeled_j_per_token": self.modeled_j_per_token,
            "spec_gamma": self.spec_gamma,
            "spec_accept_rate": self.spec_accept_rate,
            "spec_expected_accepted": self.spec_expected_accepted,
            "spec_window_seconds": self.spec_window_seconds,
            "spec_tokens_per_s_ceiling": self.spec_tokens_per_s_ceiling,
            "phase": self.phase,
            "chunk_knee_tokens": self.chunk_knee_tokens,
            "prefill_chunk_derived": self.prefill_chunk_derived,
            "ring_token_bytes": self.ring_token_bytes,
            "ring_window": self.ring_window,
            "num_ring_pages": self.num_ring_pages,
            "state_bytes_per_slot": self.state_bytes_per_slot,
        }


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024.0:
            return f"{b:.1f}{unit}"
        b /= 1024.0
    return f"{b:.1f}PB"
