"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q,k,v: (BH, S, D) -> (BH, Sq, Dv); fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
