"""Serving launcher: static batch or continuous batching.

The static decode loop is ONE jitted ``lax.scan`` (no per-token host
dispatch) — the JAX analogue of the RPU's host-free execution model.
``--continuous`` switches to iteration-level batching over the block-paged
KV cache: requests arrive as a Poisson process (``--arrival-rate`` req/s)
and are admitted into freed decode slots without recompiling.  Optionally
runs speculative decoding (paper Fig 14 setup) with a reduced draft model.

Continuous admission runs **chunked prefill** (``--prefill-chunk`` tokens
per iteration per request) interleaved with decode, and shares prompt
prefixes through the page pool's prefix index (``--num-prompts`` distinct
prompts over ``--num-requests`` requests exercises the sharing;
``--no-prefix-cache`` disables it).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 64 --max-new 32 [--speculative]
  PYTHONPATH=src python -m repro.launch.serve --continuous \
      --num-requests 16 --arrival-rate 50 --batch 4 --num-prompts 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_small_mesh
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.runtime.engine import ContinuousServeEngine, ServeEngine
from repro.runtime.scheduler import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="iteration-level batching over a paged KV cache")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request arrival rate in req/s "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--num-requests", type=int, default=0,
                    help="total requests for --continuous (default 3x batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens for --continuous")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prefill chunk size in tokens for --continuous")
    ap.add_argument("--num-prompts", type=int, default=0,
                    help="distinct prompts for --continuous (0 = all "
                         "distinct; lower values share prefixes)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable prompt-prefix page sharing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only: no decode step")
        return 1
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    mesh = make_small_mesh()
    plan = make_plan(cfg, mesh, global_batch=args.batch, shape_kind="decode")
    max_len = args.prompt_len + args.max_new

    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (args.batch, 8, cfg.d_model),
            jnp.bfloat16)
        max_len += 8

    with mesh, sharding_rules(plan.rules()):
        if args.continuous:
            n_req = args.num_requests or 3 * args.batch
            rng = np.random.default_rng(args.seed)
            gaps = (rng.exponential(1.0 / args.arrival_rate, n_req)
                    if args.arrival_rate > 0 else np.zeros(n_req))
            arrivals = np.cumsum(gaps)
            n_distinct = args.num_prompts or n_req
            pool_prompts = np.asarray(jax.random.randint(
                jax.random.fold_in(key, 4), (n_distinct, args.prompt_len), 0,
                cfg.vocab_size))
            rng_pick = np.random.default_rng(args.seed + 1)
            picks = rng_pick.integers(0, n_distinct, n_req)
            reqs = [Request(rid=i, prompt=pool_prompts[picks[i]],
                            max_new_tokens=args.max_new,
                            arrival_time=float(arrivals[i]))
                    for i in range(n_req)]
            eng = ContinuousServeEngine(
                model, params, num_slots=args.batch,
                page_size=args.page_size,
                num_pages=1 + args.batch * -(-max_len // args.page_size) * 2,
                max_len=max_len, temperature=args.temperature,
                prefill_chunk=args.prefill_chunk,
                enable_prefix_cache=args.prefix_cache)
            t0 = time.time()
            stats = eng.run(reqs, key=key)
            dt = time.time() - t0
            print(f"arch={cfg.name} continuous slots={args.batch} "
                  f"requests={n_req} rate={args.arrival_rate}/s "
                  f"steps={stats.steps} occupancy={stats.occupancy:.2f} "
                  f"preemptions={stats.preemptions}")
            print(f"tokens={stats.total_tokens} wall={dt:.2f}s "
                  f"({stats.total_tokens / dt:.1f} tok/s incl. compile)")
            print(f"prefill: {stats.chunks} chunks, "
                  f"{stats.prefill_tokens}/{stats.prompt_tokens} prompt "
                  f"tokens computed, prefix hit rate "
                  f"{stats.prefix_hit_rate:.2f}, cow={stats.cow_events}")
            q = stats.ttft_quantiles()
            if q is not None:
                print(f"ttft p50={q[0] * 1e3:.1f}ms p99={q[1] * 1e3:.1f}ms")
            per_req = " ".join(
                f"r{rid}:p{st['preemptions']}/c{st['chunks']}"
                for rid, st in sorted(stats.per_request.items()))
            print(f"per-request preemptions/chunks: {per_req}")
            print("sample:", stats.results[0][:16].tolist())
            return 0
        if args.speculative:
            from repro.runtime.speculative import speculative_generate
            import dataclasses
            draft_cfg = dataclasses.replace(
                cfg, name=cfg.name + "-draft",
                n_layers=max(2, cfg.n_layers // 4))
            draft = build_model(draft_cfg)
            draft_params = draft.init(jax.random.fold_in(key, 3))
            t0 = time.time()
            res = speculative_generate(
                draft, draft_params, model, params,
                batch["tokens"][:1], max_new_tokens=args.max_new,
                gamma=4, temperature=args.temperature, key=key)
            dt = time.time() - t0
            acc = float(res.accepted_per_window.mean()) if res.windows else 0.0
            print(f"speculative: accepted/window={acc:.2f} over {res.windows} windows")
            toks = res.tokens[None, :]
        else:
            eng = ServeEngine(model, params, max_len=max_len,
                              temperature=args.temperature)
            t0 = time.time()
            out = eng.generate(batch, max_new_tokens=args.max_new, key=key)
            dt = time.time() - t0
            toks = out.tokens

    n_tok = int(toks.shape[0] * toks.shape[1])
    print(f"arch={cfg.name} batch={args.batch} new_tokens={toks.shape[1]} "
          f"wall={dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    print("sample:", toks[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
