"""AdamW with f32 accumulators over bf16 params (pure pytree ops)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # Accumulator dtype.  f32 is the default; 400B-class configs use bf16
    # accumulators (the Gopher/PaLM recipe) so params+opt fit the pod:
    # 2 (params) + 2+2 (m,v) = 6 bytes/param instead of 10.
    state_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_opt_state(params, state_dtype: str = "float32") -> dict:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    state_dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_n = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_n = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_n / b1c
        vhat = v_n / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m_n.astype(state_dt), v_n.astype(state_dt)

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = td.flatten_up_to(grads)
    flat_m = td.flatten_up_to(opt_state["m"])
    flat_v = td.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = td.unflatten([o[0] for o in out])
    new_m = td.unflatten([o[1] for o in out])
    new_v = td.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr}
