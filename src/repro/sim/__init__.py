"""RPU simulation framework (paper contribution C4).

  isa       — CISC-style phase/program representation
  compiler  — ModelConfig -> per-CU decode-step programs (paper §VI)
  engine    — event-driven decoupled-pipeline simulator (paper Fig 8)
  gpu_model — H100/H200 analytical baseline calibrated to §II measurements
  scaling   — strong scaling, ISO-TDP, energy & cost studies (Figs 11-13)
"""
from repro.sim.isa import Phase, LayerProgram, Program
from repro.sim.compiler import CompileOptions, compile_decode_step
from repro.sim.engine import SimResult, simulate_program
