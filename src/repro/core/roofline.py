"""Three-term roofline analysis from compiled XLA artifacts.

This is the TPU-side analogue of the paper's Figure 1 roofline reasoning:
for every (architecture x input shape x mesh) dry-run we derive

    compute term    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device   / HBM_bw_per_chip
    collective term = coll_bytes_per_device  / (links x link_bw per chip)

``compiled.cost_analysis()`` reports **per-device** flops / bytes after SPMD
partitioning (verified empirically: a 512-way sharded matmul reports
total/512), so the terms divide by per-chip peaks, which is equivalent to
the "total / (chips x peak)" formulation.

Collective bytes are NOT in cost_analysis; we parse the compiled HLO text
and sum the result-operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  Async pairs
(`*-start`/`*-done`) are counted once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.core import hardware

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# matches e.g. ``= bf16[8,1024]{1,0} all-reduce(`` and tuple results of
# ``...-start`` forms; group "ty" captures the full result type string.
_COLL_RE = re.compile(
    r"=\s*(?P<ty>\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>" + "|".join(_COLL_KINDS) + r")(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(ty: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(ty):
        dtype, dims = m.group(1), m.group(2)
        size = _DTYPE_BYTES.get(dtype)
        if size is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * size
    return total


def _group_size(line: str) -> int:
    """Best-effort replica group size from an HLO collective line."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return len(first.split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective accounting (per-device bytes)."""

    count: int = 0
    operand_bytes: float = 0.0       # sum of result-operand sizes (spec metric)
    wire_bytes: float = 0.0          # ring-algorithm bytes on the wire/device


def parse_collectives(hlo_text: str) -> dict[str, CollectiveStats]:
    """Parse collective ops out of ``compiled.as_text()``."""
    stats: dict[str, CollectiveStats] = {k: CollectiveStats() for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("op")
        size = _type_bytes(m.group("ty"))
        if m.group("suffix") == "-start" and m.group("ty").startswith("("):
            # start-op tuples alias (operand, result, ...); take half to avoid
            # counting the aliased input buffer (plain forms dominate on CPU).
            size /= 2.0
        g = _group_size(line)
        s = stats[kind]
        s.count += 1
        s.operand_bytes += size
        # Ring-algorithm wire traffic per device:
        if kind == "all-reduce":
            s.wire_bytes += 2.0 * (g - 1) / max(g, 1) * size
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            s.wire_bytes += (g - 1) / max(g, 1) * size
        else:  # collective-permute: one send + one recv of the buffer
            s.wire_bytes += size
    return {k: v for k, v in stats.items() if v.count}


@dataclasses.dataclass
class RooflineReport:
    """Roofline terms for one (arch x shape x mesh) dry-run cell."""

    name: str
    chip: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_wire_bytes_per_device: float
    collective_detail: dict[str, CollectiveStats]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float | None = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower bound on step time assuming perfect overlap (max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        """Upper bound assuming zero overlap (sum of terms)."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_ratio(self) -> float | None:
        """MODEL_FLOPS / HLO_FLOPs — fraction of compiled compute that is
        algorithmically necessary (catches remat / redundancy waste)."""
        if self.model_flops_total is None:
            return None
        hlo_total = self.flops_per_device * self.n_chips
        return self.model_flops_total / hlo_total if hlo_total else None

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant-resource bound is to the serial time; 1.0
        means the three pipelines fully overlap (paper's decoupling ideal)."""
        return self.bound_s / self.serial_s if self.serial_s else 1.0

    def row(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "useful_ratio": self.useful_flops_ratio,
        }


def analyze_compiled(
    compiled,
    *,
    name: str = "",
    chip: hardware.ChipSpec = hardware.TPU_V5E,
    n_chips: int = 1,
    model_flops_total: float | None = None,
    hlo_text: str | None = None,
    trip_aware: bool = True,
) -> RooflineReport:
    """Build a RooflineReport from a ``lowered.compile()`` artifact.

    ``trip_aware=True`` (default) walks the compiled HLO with
    ``core.hlo_cost`` so while-loop (``lax.scan``) bodies are multiplied by
    their trip counts — XLA's ``cost_analysis()`` counts each body once,
    undercounting a 48-layer scanned stack ~48x.  The partitioned module is
    the per-device program, so all numbers are per-device.
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    link_bw = chip.ici_link_bw * chip.ici_links
    if trip_aware:
        from repro.core.hlo_cost import analyze_hlo_text
        cost = analyze_hlo_text(text)
        flops = cost.flops
        bytes_accessed = cost.bytes
        colls = {
            k: CollectiveStats(count=int(cost.coll_count.get(k, 0)),
                               operand_bytes=cost.coll_bytes.get(k, 0.0),
                               wire_bytes=cost.coll_wire_bytes.get(k, 0.0))
            for k in cost.coll_bytes
        }
    else:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older API returned [dict]
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        colls = parse_collectives(text)
    coll_bytes = sum(s.operand_bytes for s in colls.values())
    wire_bytes = sum(s.wire_bytes for s in colls.values())
    return RooflineReport(
        name=name,
        chip=chip.name,
        n_chips=n_chips,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=coll_bytes,
        collective_wire_bytes_per_device=wire_bytes,
        collective_detail=colls,
        compute_s=flops / chip.peak_flops_bf16,
        memory_s=bytes_accessed / chip.hbm_bw,
        # spec metric: operand bytes / aggregate link bw.  (wire_bytes is
        # the ring-algorithm estimate, reported alongside.)
        collective_s=coll_bytes / link_bw,
        model_flops_total=model_flops_total,
    )


def model_flops_estimate(n_params_active: float, tokens: float,
                         training: bool) -> float:
    """MODEL_FLOPS = 6*N*D (train fwd+bwd) or 2*N*D (inference) per the
    standard accounting; for MoE use active (routed-in) parameters."""
    per_token = (6.0 if training else 2.0) * n_params_active
    return per_token * tokens
