"""End-to-end training driver: a ~100M-parameter dense LM for a few hundred
steps with the full production loop — sharded via ParallelPlan, periodic
checkpoints, NaN rollback, straggler-tolerant data, and a mid-run injected
node failure that the loop recovers from.

  PYTHONPATH=src python examples/train_small.py [--steps 300] [--no-failure]

(CPU note: ~100M params is real work for a laptop CPU; pass --steps 30 for
a fast smoke run. The same entry point drives the TPU mesh unchanged.)
"""
import argparse
import logging

import jax

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_small_mesh
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

# ~100M-parameter llama-style config (12L x 768 ~ GPT-2-small scale + SwiGLU)
CONFIG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=2048, vocab_size=32000, rope_theta=10000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--no-failure", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    model = build_model(CONFIG_100M)
    mesh = make_small_mesh()
    plan = make_plan(CONFIG_100M, mesh, global_batch=args.batch,
                     shape_kind="train")

    state = init_train_state(model, jax.random.PRNGKey(0))
    n = model.param_count(state.params)
    print(f"model: {CONFIG_100M.name}, {n/1e6:.1f}M params")

    opt = AdamWConfig(lr=3e-4, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps)
    step_fn = make_train_step(model, opt)
    pipeline = SyntheticTokenPipeline(CONFIG_100M, global_batch=args.batch,
                                      seq_len=args.seq,
                                      straggler_timeout_s=5.0)

    failure = None
    if not args.no_failure:
        fired = {"done": False}

        def failure(step):
            # simulate one node failure at 60% of the run
            if step == int(args.steps * 0.6) and not fired["done"]:
                fired["done"] = True
                return True
            return False

    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=max(args.steps // 6, 2),
                          ckpt_dir=args.ckpt_dir, log_every=10)
    with mesh, sharding_rules(plan.rules()):
        result = run_training(step_fn, state, pipeline, loop_cfg,
                              failure_fn=failure)

    print(f"done: {len(result.losses)} steps, loss "
          f"{result.losses[0]:.3f} -> {result.losses[-1]:.3f}, "
          f"rollbacks={result.rollbacks}, "
          f"straggler_fallbacks={result.straggler_fallbacks}")


if __name__ == "__main__":
    main()
