import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this lowers + compiles
the real step function (train_step / prefill / serve_step) against
ShapeDtypeStruct stand-ins on the production mesh — 16x16 single-pod and
2x16x16 multi-pod — and records:

  * ``compiled.memory_analysis()``  (bytes/device: proves the cell fits)
  * ``compiled.cost_analysis()``    (FLOPs / bytes for the roofline terms)
  * the collective schedule parsed from the compiled HLO

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` and are
summarized into EXPERIMENTS.md §Dry-run / §Roofline by
``benchmarks/roofline_table.py``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import hardware, roofline
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch import shapes as shp
from repro.models.footprint import compute_footprint
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.runtime.engine import serve_step_fn
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step, TrainState

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# bf16 AdamW accumulators above this weight budget (400B-class cells).
_BF16_OPT_THRESHOLD_PARAMS = 5e10


def _model_flops(cfg, fp, shape: shp.ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * fp.active_params * b * s
    if shape.kind == "prefill":
        return 2.0 * fp.active_params * b * s
    return fp.decode_flops_per_token(b, s)


def _lower_cell(cfg, shape: shp.ShapeSpec, mesh):
    """Build (step_fn, args_sds, in_shardings) for one cell."""
    model = build_model(cfg)
    plan = make_plan(cfg, mesh, global_batch=shape.global_batch,
                     shape_kind=shape.kind)
    fp = compute_footprint(cfg)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            state_dtype=("bfloat16" if fp.total_params > _BF16_OPT_THRESHOLD_PARAMS
                         else "float32"))
        # shard_map EP crashes XLA:CPU's partitioner under AD (see
        # models/moe.py); training uses the GSPMD-hinted capacity path.
        model = build_model(cfg, moe_impl="capacity")
        step = make_train_step(model, opt_cfg, remat=True)
        state_sds = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0),
                                     state_dtype=opt_cfg.state_dtype))
        state_sh = TrainState(
            params=plan.param_shardings(state_sds.params),
            opt_state=plan.param_shardings(state_sds.opt_state),
            err=None)
        batch_sds = shp.batch_specs(cfg, shape)
        batch_sh = plan.batch_shardings(batch_sds)
        return plan, step, (state_sds, batch_sds), (state_sh, batch_sh)

    if shape.kind == "prefill":
        params_sds = shp.param_specs(model)
        params_sh = plan.param_shardings(params_sds)
        batch_sds = shp.batch_specs(cfg, shape)
        batch_sh = plan.batch_shardings(batch_sds)
        if not cfg.has_decode:
            def step(params, batch):
                return model.forward(params, batch)
            return plan, step, (params_sds, batch_sds), (params_sh, batch_sh)
        model_nc = build_model(cfg)
        cache_sds = jax.eval_shape(
            lambda: model_nc.init_cache(shape.global_batch, shape.seq_len))
        cache_sh = plan.cache_shardings(cache_sds)

        def step(params, batch, cache):
            return model_nc.prefill(params, batch, cache)
        return plan, step, (params_sds, batch_sds, cache_sds), \
            (params_sh, batch_sh, cache_sh)

    # decode / long_decode
    params_sds = shp.param_specs(model)
    params_sh = plan.param_shardings(params_sds)
    tokens_sds, cache_sds, pos_sds = shp.decode_specs(cfg, shape, model)
    tokens_sh = plan.batch_shardings({"t": tokens_sds})["t"]
    cache_sh = plan.cache_shardings(cache_sds)
    from jax.sharding import NamedSharding, PartitionSpec as P
    pos_sh = NamedSharding(mesh, P())
    step = serve_step_fn(model)
    return plan, step, (params_sds, tokens_sds, cache_sds, pos_sds), \
        (params_sh, tokens_sh, cache_sh, pos_sh)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path = OUT_DIR) -> dict:
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    ok, reason = shp.cell_supported(cfg, shape)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "skip", "reason": reason}
    if not ok:
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_chip_count(mesh)
    fp = compute_footprint(cfg)

    t0 = time.time()
    plan, step, args_sds, in_sh = _lower_cell(cfg, shape, mesh)
    with mesh, sharding_rules(plan.rules()):
        lowered = jax.jit(step, in_shardings=in_sh).lower(*args_sds)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(mem, attr):
                mem_info[attr] = int(getattr(mem, attr))

    report = roofline.analyze_compiled(
        compiled, name=cell, chip=hardware.TPU_V5E, n_chips=n_chips,
        model_flops_total=_model_flops(cfg, fp, shape))

    record.update({
        "status": "ok",
        "compile_s": round(t1 - t0, 2),
        "chips": n_chips,
        "plan": {"dp": list(plan.dp), "tp": list(plan.tp) if isinstance(plan.tp, tuple) else plan.tp,
                 "fsdp": list(plan.fsdp), "cache_seq": (list(plan.cache_seq) if isinstance(plan.cache_seq, tuple) else plan.cache_seq),
                 "seq_parallel": plan.seq_parallel},
        "memory_analysis": mem_info,
        "flops_per_device": report.flops_per_device,
        "bytes_per_device": report.bytes_per_device,
        "collective_bytes_per_device": report.collective_bytes_per_device,
        "collective_wire_bytes_per_device": report.collective_wire_bytes_per_device,
        "collectives": {k: dataclasses.asdict(v)
                        for k, v in report.collective_detail.items()},
        "compute_s": report.compute_s,
        "memory_s": report.memory_s,
        "collective_s": report.collective_s,
        "dominant": report.dominant,
        "bound_s": report.bound_s,
        "model_flops_total": report.model_flops_total,
        "useful_flops_ratio": report.useful_flops_ratio,
    })
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(record, indent=1))
    return record


def _print_record(r: dict):
    if r["status"] == "skip":
        print(f"SKIP {r['arch']} x {r['shape']} [{r['mesh']}]: {r['reason']}")
        return
    print(f"OK   {r['arch']} x {r['shape']} [{r['mesh']}] "
          f"compile={r['compile_s']}s dominant={r['dominant']} "
          f"comp={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
          f"coll={r['collective_s']*1e3:.2f}ms "
          f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)}")
    if r.get("memory_analysis"):
        m = r["memory_analysis"]
        args = m.get("argument_size_in_bytes", 0)
        tmp = m.get("temp_size_in_bytes", 0)
        print(f"     memory/device: args={args/2**30:.2f}GiB temp={tmp/2**30:.2f}GiB "
              f"(v5e HBM 16GiB)")


def all_cells(multi_pod_only: bool | None = None):
    for arch in ASSIGNED_ARCHS:
        for shape_name in shp.SHAPES:
            for mp in ((False, True) if multi_pod_only is None else (multi_pod_only,)):
                yield arch, shape_name, mp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="with --all: isolate each cell in a subprocess")
    args = ap.parse_args(argv)

    if args.list:
        for arch, shape_name, mp in all_cells():
            cfg = get_config(arch)
            ok, reason = shp.cell_supported(cfg, shp.SHAPES[shape_name])
            mesh = "2x16x16" if mp else "16x16"
            print(f"{arch:28s} {shape_name:12s} {mesh:8s} "
                  f"{'RUN' if ok else 'SKIP: ' + reason}")
        return 0

    if args.all:
        mp_filter = True if args.multi_pod else (False if args.single_pod else None)
        failures = []
        for arch, shape_name, mp in all_cells(mp_filter):
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name]
                cmd.append("--multi-pod" if mp else "--single-pod")
                rc = subprocess.run(cmd, env={**os.environ}).returncode
                if rc != 0:
                    failures.append((arch, shape_name, mp))
            else:
                try:
                    _print_record(run_cell(arch, shape_name, mp))
                except Exception:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mp))
        if failures:
            print(f"\n{len(failures)} FAILURES:")
            for f in failures:
                print("  ", f)
            return 1
        print("\nall cells green")
        return 0

    assert args.arch and args.shape, "--arch and --shape (or --all / --list)"
    mp = bool(args.multi_pod)
    try:
        rec = run_cell(args.arch, args.shape, mp)
    except Exception:
        traceback.print_exc()
        return 1
    _print_record(rec)
    return 0 if rec["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
