"""Paged KV cache + continuous batching: allocator/ref-count invariants,
prefix-cache sharing and copy-on-write semantics, paged-vs-dense attention
equivalence, and end-to-end engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_decode_attention_ref,
)
from repro.models.model import build_model
from repro.runtime.engine import (ContinuousServeEngine, DisaggServeEngine,
                                  ServeEngine)
from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator, PagedKVCache
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.speculative import SpeculativeConfig


# ---------------------------------------------------------------------------
# PageAllocator invariants
# ---------------------------------------------------------------------------


def test_allocator_exclusive_ownership_and_conservation():
    a = PageAllocator(num_pages=17, page_size=8)
    rng = np.random.default_rng(0)
    owners = {}
    for step in range(200):
        op = rng.integers(0, 3)
        if op < 2:                                     # alloc
            owner = int(rng.integers(0, 6))
            got = a.alloc(owner, int(rng.integers(1, 4)))
            if got is not None:
                owners.setdefault(owner, []).extend(got)
                assert SCRATCH_PAGE not in got
        else:                                          # free
            if owners:
                owner = int(rng.choice(list(owners)))
                n = a.free_owner(owner)
                assert n == len(owners.pop(owner))
        a.check()                                      # exclusive + conserved
    # every page accounted for at the end
    assert a.num_free + a.num_live == a.num_pages - 1


def test_allocator_alloc_is_all_or_nothing():
    a = PageAllocator(num_pages=5, page_size=8)        # 4 usable
    assert a.alloc("x", 3) is not None
    before = a.num_free
    assert a.alloc("y", 2) is None                     # only 1 left
    assert a.num_free == before                        # nothing leaked
    a.check()


def test_allocator_defrag_compacts_and_preserves_ownership():
    a = PageAllocator(num_pages=12, page_size=8)
    pa = a.alloc("a", 3)
    pb = a.alloc("b", 3)
    pc = a.alloc("c", 2)
    a.free_owner("b")                                  # hole in the middle
    before = {o: a.pages_of(o) for o in ("a", "c")}
    mapping = a.defrag()
    a.check()
    # live pages now occupy the lowest ids, scratch excluded
    live = sorted(p for o in ("a", "c") for p in a.pages_of(o))
    assert live == list(range(1, 1 + len(pa) + len(pc)))
    # mapping relocates exactly the moved pages, injectively
    assert len(set(mapping.values())) == len(mapping)
    for owner in ("a", "c"):
        moved = [mapping.get(p, p) for p in before[owner]]
        assert moved == a.pages_of(owner)


def test_paged_cache_admit_grow_release_and_eviction():
    c = PagedKVCache(num_slots=2, num_pages=7, page_size=4, max_blocks=4)
    assert c.admit(0, 6) == 0                          # 2 pages, no prefix
    assert c.blocks_of(0) == 2
    assert c.admit(1, 9) == 0                          # 3 pages
    assert c.allocator.num_free == 1
    assert c.ensure(0, 8)                              # grow slot 0 -> 3 pages
    table = c.table()
    live0 = set(table[0, :3].tolist())
    live1 = set(table[1, :3].tolist())
    assert SCRATCH_PAGE not in live0 | live1
    assert not live0 & live1                           # exclusive pages
    assert (table[0, 3:] == SCRATCH_PAGE).all()        # unallocated -> scratch
    # pool exhausted: growth fails, release (eviction) frees it
    assert not c.ensure(1, 14)
    freed = c.release(1)
    assert freed == 3
    assert (c.table()[1] == SCRATCH_PAGE).all()
    assert c.ensure(0, 14)                             # now it fits
    c.allocator.check()


def test_allocator_share_refcounts_conserved_random_workload():
    """Shared ownership: ref-counts equal owner-list entries, never go
    negative (asserted inside the allocator on every drop), and the
    free/live partition stays conserved under random alloc/share/free."""
    a = PageAllocator(num_pages=19, page_size=4)
    rng = np.random.default_rng(7)
    owners: dict[int, list[int]] = {}
    for step in range(400):
        op = rng.integers(0, 4)
        if op == 0:                                    # exclusive alloc
            o = int(rng.integers(0, 6))
            got = a.alloc(o, int(rng.integers(1, 3)))
            if got is not None:
                owners.setdefault(o, []).extend(got)
        elif op == 1 and owners:                       # share a live page
            donor = int(rng.choice(list(owners)))
            if owners[donor]:
                o = int(rng.integers(6, 10))
                p = int(rng.choice(owners[donor]))
                a.share(o, [p])
                owners.setdefault(o, []).append(p)
        elif op == 2 and owners:                       # drop one reference
            o = int(rng.choice(list(owners)))
            if owners[o]:
                p = owners[o].pop(int(rng.integers(0, len(owners[o]))))
                a.drop_page(o, p)
                if not owners[o]:
                    owners.pop(o)
        elif op == 3 and owners:                       # drop a whole owner
            o = int(rng.choice(list(owners)))
            a.free_owner(o)
            owners.pop(o)
        a.check()
    for o, pages in owners.items():
        for p in pages:
            assert a.refcount(p) >= 1
    assert a.num_free + a.num_live == a.num_pages - 1


def test_prefix_admit_shares_pages_and_pins_them():
    """A second admission of the same prompt shares the donor's full blocks
    read-only; matched pages are pinned before fresh allocation, so the
    reclaim path can never free-and-reissue a matched page (which would
    alias two table entries)."""
    ps = 4
    prompt = np.arange(13, dtype=np.int32)             # 3 full blocks + 1
    c = PagedKVCache(num_slots=3, num_pages=9, page_size=ps, max_blocks=4,
                     enable_prefix_cache=True)
    assert c.admit(0, len(prompt), tokens=prompt) == 0
    c.index_prompt(0, prompt)                          # prefill "completed"
    donor_row = c.table()[0].copy()
    # a second identical prompt shares (13-1)//4 = 3 full blocks
    assert c.admit(1, len(prompt), tokens=prompt) == 3 * ps
    np.testing.assert_array_equal(c.table()[1, :3], donor_row[:3])
    assert c.table()[1, 3] != donor_row[3]             # private last block
    for b in range(3):
        assert c.allocator.refcount(int(donor_row[b])) == 3  # 2 slots + index
    c.allocator.check()
    # donor finishes: shared pages stay resident under the index + slot 1
    c.release(0)
    for b in range(3):
        assert c.allocator.refcount(int(donor_row[b])) == 2
    # regression: release slot 1 too, then re-admit under a tight pool so
    # fresh allocation must reclaim — the matched pages must never show up
    # again as the fresh page of the same row
    c.release(1)
    shared = c.admit(2, len(prompt), tokens=prompt)
    assert shared == 3 * ps
    row = c.table()[2]
    live = [int(p) for p in row if p != SCRATCH_PAGE]
    assert len(set(live)) == len(live), f"aliased pages in one row: {row}"
    c.allocator.check()


def test_cow_detaches_shared_page_and_donor_is_untouched():
    ps = 4
    prompt = np.arange(9, dtype=np.int32)              # 2 full blocks + 1
    c = PagedKVCache(num_slots=2, num_pages=12, page_size=ps, max_blocks=3,
                     enable_prefix_cache=True)
    c.admit(0, len(prompt), tokens=prompt)
    c.index_prompt(0, prompt)
    c.admit(1, len(prompt), tokens=prompt)
    donor_row = c.table()[0].copy()
    assert c.page_shared(1, 0)
    moved = c.cow(1, 0)
    assert moved is not None
    old, new = moved
    assert old == donor_row[0] and new != old
    assert c.table()[1, 0] == new
    np.testing.assert_array_equal(c.table()[0], donor_row)   # donor untouched
    assert c.allocator.refcount(old) == 2              # slot 0 + index
    assert c.allocator.refcount(new) == 1
    assert c.cow(1, 0) is None                         # already exclusive
    c.allocator.check()


def test_engine_page_copy_leaves_donor_bytes_identical():
    """The device half of copy-on-write: ``_copy_page`` duplicates a page
    across every pool leaf without perturbing any other page."""
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                                num_pages=8, max_len=16)
    pools = model.init_paged_cache(8, 4)
    pools = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(a.size % 97), a.shape,
                                    jnp.float32).astype(a.dtype), pools)
    before = jax.tree.map(lambda a: np.asarray(a).copy(), pools)
    out = eng._copy_page(pools, jnp.int32(5), jnp.int32(2))

    for si, seg in enumerate(model.plan):
        ax = 0 if seg.reps == 1 else 1            # page axis per stacking
        for ki in range(len(seg.kinds)):
            for leaf in before[si][ki]:
                b = before[si][ki][leaf]
                o = np.asarray(out[si][ki][leaf])
                np.testing.assert_array_equal(
                    np.take(o, 5, axis=ax), np.take(b, 2, axis=ax))  # copied
                keep = [i for i in range(b.shape[ax]) if i != 5]
                np.testing.assert_array_equal(    # donor + all others intact
                    np.take(o, keep, axis=ax), np.take(b, keep, axis=ax))


def test_defrag_preserves_shared_page_aliasing():
    ps = 4
    prompt = np.arange(9, dtype=np.int32)
    c = PagedKVCache(num_slots=3, num_pages=16, page_size=ps, max_blocks=3,
                     enable_prefix_cache=True)
    other = np.arange(100, 109, dtype=np.int32)
    c.admit(2, len(other), tokens=other)               # low page ids
    c.admit(0, len(prompt), tokens=prompt)
    c.index_prompt(0, prompt)
    c.admit(1, len(prompt), tokens=prompt)             # shares 2 blocks
    c.release(2)                                       # hole below the rest
    assert c.table()[0, 0] == c.table()[1, 0]          # aliased before
    gather = c.defrag()
    assert gather is not None
    # aliasing preserved: both tables still name the SAME physical page
    np.testing.assert_array_equal(c.table()[0, :2], c.table()[1, :2])
    assert c.table()[0, 2] != c.table()[1, 2]
    c.allocator.check()
    # prefix index was remapped with the tables: a third identical prompt
    # still hits the same (moved) pages
    shared = c.admit(2, len(prompt), tokens=prompt)
    assert shared == 2 * ps
    np.testing.assert_array_equal(c.table()[2, :2], c.table()[0, :2])
    c.allocator.check()


def test_scheduler_next_arrival_is_queue_head():
    c = PagedKVCache(num_slots=1, num_pages=4, page_size=4, max_blocks=2)
    s = Scheduler(c)
    assert s.next_arrival() is None
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new_tokens=1,
                    arrival_time=t) for i, t in enumerate([0.5, 0.1, 0.9])]
    s.submit(reqs)
    assert s.next_arrival() == 0.1                     # sorted on submit
    got = s.admit(now=0.2)
    assert [r.rid for r in got] == [1]
    assert s.next_arrival() == 0.5
    # a second submit with an earlier arrival re-sorts the queue, so the
    # O(1) head read stays the minimum
    s.submit([Request(rid=3, prompt=np.zeros(2, np.int32), max_new_tokens=1,
                      arrival_time=0.3)])
    assert s.next_arrival() == 0.3
    assert [r.arrival_time for r in s.waiting] == [0.3, 0.5, 0.9]


def test_scheduler_eviction_restarts_youngest():
    c = PagedKVCache(num_slots=2, num_pages=5, page_size=4, max_blocks=4)
    s = Scheduler(c)
    r0 = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=32)
    r1 = Request(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=32)
    s.submit([r0, r1])
    assert {r.rid for r in s.admit(0.0)} == {0, 1}
    # drive r0's position until the pool (4 usable pages) is exhausted;
    # r1 (younger) must be evicted back to the queue with its pages freed
    r0.pos = 8
    assert s.ensure_capacity(r0)
    r0.pos = 12
    assert s.ensure_capacity(r0)
    assert r1.state == "pending" and r1.preemptions == 1
    assert r1 in s.waiting and 1 not in {r.rid for r in s.running.values()}
    c.allocator.check()


# ---------------------------------------------------------------------------
# Paged vs dense decode attention (exact, by construction)
# ---------------------------------------------------------------------------


def test_paged_decode_attention_matches_dense_exactly():
    key = jax.random.PRNGKey(0)
    B, H, KVH, D, page, n_blocks = 3, 4, 2, 16, 4, 5
    S = page * n_blocks
    pos = jnp.asarray([5, 0, S - 1], jnp.int32)        # ragged positions
    q = jax.random.normal(key, (B, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KVH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KVH, D))

    # scatter the dense cache into a pool under a random page permutation
    P = 1 + B * n_blocks
    perm = np.random.default_rng(0).permutation(np.arange(1, P))
    table = perm.reshape(B, n_blocks).astype(np.int32)
    k_pages = jnp.zeros((P, page, KVH, D), k.dtype).at[table.reshape(-1)].set(
        k.reshape(B * n_blocks, page, KVH, D))
    v_pages = jnp.zeros((P, page, KVH, D), v.dtype).at[table.reshape(-1)].set(
        v.reshape(B * n_blocks, page, KVH, D))

    dense = decode_attention_ref(q, k, v, pos + 1)
    paged = paged_decode_attention_ref(q, k_pages, v_pages,
                                       jnp.asarray(table), pos)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


def test_paged_decode_attention_window_mask():
    key = jax.random.PRNGKey(3)
    B, H, D, page, n_blocks, W = 1, 2, 8, 4, 3, 4
    S = page * n_blocks
    pos = jnp.asarray([9], jnp.int32)
    q = jax.random.normal(key, (B, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    table = jnp.arange(1, 1 + n_blocks, dtype=jnp.int32)[None, :]
    k_pages = jnp.zeros((1 + n_blocks, page, H, D)).at[table[0]].set(
        k.reshape(n_blocks, page, H, D))
    v_pages = jnp.zeros((1 + n_blocks, page, H, D)).at[table[0]].set(
        v.reshape(n_blocks, page, H, D))
    valid = (jnp.arange(S) <= 9) & (jnp.arange(S) > 9 - W)
    dense = decode_attention_ref(q, k, v, None, valid=valid[None])
    paged = paged_decode_attention_ref(q, k_pages, v_pages, table, pos,
                                       window=W)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


# ---------------------------------------------------------------------------
# End-to-end: continuous engine == static engine, token for token
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_engine_matches_static_greedy(small):
    cfg, model, params = small
    B, S, G = 4, 12, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    eng = ServeEngine(model, params, max_len=S + G + 1, donate_cache=False)
    ref = eng.generate({"tokens": toks}, max_new_tokens=G)

    # page-aligned max_len so the paged gather width equals the dense width
    ceng = ContinuousServeEngine(model, params, num_slots=B, page_size=8,
                                 num_pages=64, max_len=S + G + 1)
    reqs = [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G)
            for i in range(B)]
    stats = ceng.run(reqs)
    cont = np.stack([stats.results[i] for i in range(B)])
    np.testing.assert_array_equal(np.asarray(ref.tokens), cont)
    assert stats.occupancy == 1.0                      # all slots busy


def test_continuous_engine_chunked_prefill_prefix_reuse_matches_static(small):
    """Shared-prompt traffic through chunked prefill + the prefix cache:
    later requests skip their shared full blocks yet reproduce the static
    engine's greedy tokens exactly."""
    cfg, model, params = small
    B, S, G = 6, 12, 6
    base = jax.random.randint(jax.random.PRNGKey(5), (2, S), 0, cfg.vocab_size)
    prompts = np.asarray(base)[np.array([0, 1, 0, 1, 0, 0])]   # 2 distinct
    eng = ServeEngine(model, params, max_len=S + G + 1, donate_cache=False)
    refs = {i: np.asarray(eng.generate(
        {"tokens": jnp.asarray(prompts[i:i + 1])},
        max_new_tokens=G).tokens[0]) for i in range(B)}

    ceng = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                 num_pages=48, max_len=S + G + 1,
                                 prefill_chunk=5,       # 12 tokens -> 3 chunks
                                 enable_prefix_cache=True)
    # staggered so early requests complete prefill (and get indexed)
    # before their twins arrive
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=G,
                    arrival_time=0.05 * i) for i in range(B)]
    stats = ceng.run(reqs)
    for i in range(B):
        np.testing.assert_array_equal(refs[i], stats.results[i])
    assert stats.prefix_hit_tokens > 0                 # sharing happened
    assert stats.chunks > B                            # prompts were chunked
    # prefix hits skip recompute: fewer prompt tokens computed than admitted
    assert stats.prefill_tokens < stats.prompt_tokens
    hit = [r for r in stats.per_request.values() if r["shared_tokens"] > 0]
    assert hit and all(r["ttft"] is not None for r in stats.per_request.values())


@pytest.mark.slow
def test_continuous_engine_ragged_eviction_defrag(small):
    """Ragged lengths + staggered arrivals + pool pressure (evictions) +
    periodic defrag + prefix reuse across preemption-restarts must still
    reproduce per-request greedy exactly."""
    cfg, model, params = small
    R, S = 6, 12
    lens = [3, 7, 12, 5, 9, 1]
    toks = jax.random.randint(jax.random.PRNGKey(2), (R, S), 0, cfg.vocab_size)
    eng = ServeEngine(model, params, max_len=40, donate_cache=False)
    refs = {i: np.asarray(eng.generate({"tokens": toks[i:i + 1]},
                                       max_new_tokens=lens[i]).tokens[0])
            for i in range(R)}

    ceng = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                 num_pages=12, max_len=28,
                                 enable_prefix_cache=True)
    reqs = [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=lens[i],
                    arrival_time=0.002 * i) for i in range(R)]
    stats = ceng.run(reqs, defrag_every=3)
    for i in range(R):
        np.testing.assert_array_equal(refs[i], stats.results[i])
    assert stats.preemptions > 0                       # pressure was real


@pytest.mark.slow
def test_continuous_engine_matches_static_greedy_mla():
    """Same equivalence through the paged MLA (latent) cache path."""
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, G = 2, 8, 6
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    eng = ServeEngine(model, params, max_len=S + G + 1, donate_cache=False)
    ref = eng.generate({"tokens": toks}, max_new_tokens=G)
    ceng = ContinuousServeEngine(model, params, num_slots=B, page_size=4,
                                 num_pages=32, max_len=S + G + 1)
    reqs = [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G)
            for i in range(B)]
    stats = ceng.run(reqs)
    cont = np.stack([stats.results[i] for i in range(B)])
    np.testing.assert_array_equal(np.asarray(ref.tokens), cont)


def test_continuous_engine_matches_static_greedy_sliding_window():
    """Sliding-window masks through the gqa backend's paged dispatch: a
    SWA arch (prompt longer than the window) serves continuously and
    matches the static engine's ring-cache decode token for token.  The
    engine serves this arch through the ring space
    (``runtime.state_cache``): pages wholly behind the window are
    reclaimed mid-stream, which is logit-neutral because the sliding
    mask already excludes those positions — this test pins that."""
    cfg = reduced_config(get_config("h2o-danube-1-8b"))
    assert cfg.sliding_window is not None
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, G = 3, 12, 8                     # S > window (8): mask is live
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    eng = ServeEngine(model, params, max_len=S + G + 1, donate_cache=False)
    ref = eng.generate({"tokens": toks}, max_new_tokens=G)
    ceng = ContinuousServeEngine(model, params, num_slots=B, page_size=4,
                                 num_pages=48, max_len=S + G + 1,
                                 prefill_chunk=5)     # chunked SWA prefill
    reqs = [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G)
            for i in range(B)]
    stats = ceng.run(reqs)
    cont = np.stack([stats.results[i] for i in range(B)])
    np.testing.assert_array_equal(np.asarray(ref.tokens), cont)


def test_unsupported_stateful_combinations_raise():
    """SSM/hybrid archs serve through state pools now
    (``runtime.state_cache``), so pool construction no longer raises —
    what raises is (a) driving a state-carrying model without threading
    its states and (b) engine combinations the state protocol cannot
    support (speculative draft/verify rewinds, which recurrent state
    cannot follow)."""
    cfg = reduced_config(get_config("mamba2-370m"))
    model = build_model(cfg)
    pools = model.init_paged_cache(8, 4)          # no longer raises
    table = jnp.zeros((2, 2), jnp.int32)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="state"):
        model.decode_step_paged(params, jnp.zeros((2,), jnp.int32), pools,
                                table, jnp.zeros((2,), jnp.int32))
    with pytest.raises(NotImplementedError, match="state"):
        model.prefill_chunk_paged(params, jnp.zeros((2, 4), jnp.int32),
                                  pools, table, jnp.zeros((2,), jnp.int32),
                                  jnp.zeros((2,), jnp.int32))
    # an SSM/hybrid DRAFT is rejected at config construction...
    with pytest.raises(ValueError, match="rewindable"):
        SpeculativeConfig(draft_model=model, draft_params=params)
    # ...and a stateful TARGET at engine construction (self-draft)
    with pytest.raises(NotImplementedError, match="speculative"):
        ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                              num_pages=8, max_len=8,
                              speculative=SpeculativeConfig(gamma=2))


# ---------------------------------------------------------------------------
# Disaggregated handoff: page-chain transfer invariants
# ---------------------------------------------------------------------------


def _page_bytes(model, pools, page: int) -> dict:
    """Every pool leaf's bytes for one physical page, keyed by
    (segment, kind, leaf name) — the unit the handoff must move intact."""
    out = {}
    for si, seg in enumerate(model.plan):
        ax = 0 if seg.reps == 1 else 1            # page axis per stacking
        for ki in range(len(seg.kinds)):
            for leaf in pools[si][ki]:
                arr = np.asarray(pools[si][ki][leaf])
                out[(si, ki, leaf)] = np.take(arr, page, axis=ax).copy()
    return out


def _assert_conserved(cache) -> None:
    a = cache.allocator
    a.check()
    assert a.num_free + a.num_live == a.num_pages - 1


def test_handoff_refcount_conservation_every_step(small):
    """Ref-counts stay conserved on BOTH allocators through a full
    disaggregated serve: transfer releases the prefill slot, admission
    may prefix-share on the decode side, and no page leaks or
    double-frees survive either pool."""
    cfg, model, params = small
    eng = DisaggServeEngine(model, params, num_slots=3, page_size=4,
                            num_pages=24, max_len=32, prefill_chunk=5,
                            enable_prefix_cache=True)
    base = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    prompts = np.asarray(base)[np.array([0, 1, 0, 1, 0, 0])]
    for i in range(len(prompts)):
        eng.add_request(Request(rid=i, prompt=prompts[i], max_new_tokens=6))
    steps = 0
    while eng.has_unfinished():
        eng.step()
        _assert_conserved(eng.prefill.cache)
        _assert_conserved(eng.decode.cache)
        steps += 1
        assert steps < 500, "disaggregated serve did not converge"
    assert eng.handoff.transfers == len(prompts)
    assert eng.handoff.shared_tokens > 0          # decode-side prefix hits
    # all slots drained: live pages are exactly the indexed prefix pages
    _assert_conserved(eng.prefill.cache)
    _assert_conserved(eng.decode.cache)


def test_handoff_cow_donor_bytes_identical(small):
    """A transferred chain lands in the decode prefix index with its
    hashes intact; a second request sharing it must never perturb the
    donor's page bytes through its own handoff + decode writes."""
    cfg, model, params = small
    eng = DisaggServeEngine(model, params, num_slots=2, page_size=4,
                            num_pages=24, max_len=32, prefill_chunk=5,
                            enable_prefix_cache=True)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (13,), 0,
                                           cfg.vocab_size))
    a = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.add_request(a)
    steps = 0
    while eng.handoff.transfers == 0:
        eng.step()
        steps += 1
        assert steps < 100, "first chain never transferred"
    # after transfer, a.slot is the DECODE-side slot; its full prompt
    # blocks are the shareable donor pages
    donor = eng.decode.cache.chain(a.slot, a.prompt_len)[:3]
    snap = {p: _page_bytes(model, eng.decode._pools, p) for p in donor}
    while eng.has_unfinished():
        eng.step()
    # same prompt again: handoff admission shares the donor's full blocks
    b = Request(rid=1, prompt=prompt.copy(), max_new_tokens=6)
    eng.add_request(b)
    while eng.has_unfinished():
        eng.step()
    assert b.shared_tokens == 12                  # 3 full blocks matched
    assert eng.handoff.shared_tokens >= 12
    for p in donor:
        after = _page_bytes(model, eng.decode._pools, p)
        for key, before in snap[p].items():
            np.testing.assert_array_equal(
                after[key], before,
                err_msg=f"donor page {p} leaf {key} perturbed")
    _assert_conserved(eng.decode.cache)


def test_handoff_moves_quantized_scale_leaves(small):
    """fp8 page pools carry per-token k_scale/v_scale metadata leaves;
    the handoff must move them with the codes, byte for byte, or the
    decode side dequantizes garbage."""
    cfg, model, params = small
    eng = DisaggServeEngine(model, params, num_slots=2, page_size=4,
                            num_pages=16, max_len=32, prefill_chunk=4,
                            cache_dtype="fp8", enable_prefix_cache=True)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (11,), 0,
                                           cfg.vocab_size))
    r = Request(rid=0, prompt=prompt, max_new_tokens=4)
    eng.add_request(r)
    steps = 0
    while not eng.prefill.handoff_ready():
        eng.prefill.step()
        steps += 1
        assert steps < 100, "prefill never parked the chain"
    src_chain = eng.prefill.cache.chain(r.slot, r.prompt_len)
    src = [_page_bytes(model, eng.prefill._pools, p) for p in src_chain]
    assert eng.handoff.transfer(r, 0.0)
    dst_chain = eng.decode.cache.chain(r.slot, r.prompt_len)
    assert len(dst_chain) == len(src_chain)
    leaf_names = set()
    for s, d in zip(src, dst_chain):
        got = _page_bytes(model, eng.decode._pools, d)
        for key, before in s.items():
            leaf_names.add(key[2])
            np.testing.assert_array_equal(
                got[key], before, err_msg=f"leaf {key} lost in transfer")
    assert {"k_scale", "v_scale"} <= leaf_names   # the metadata travelled
    assert eng.handoff.pages_moved == len(src_chain)
    assert eng.handoff.bytes_moved > 0
    _assert_conserved(eng.prefill.cache)
    _assert_conserved(eng.decode.cache)
