"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000, vocab_pad_multiple=512,
    sliding_window=4096,      # SWA => runs the long_500k shape
    rope_theta=10000.0,
)
