"""Training loop: convergence, checkpoint/restart, fault injection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.train_step import TrainState, init_train_state, make_train_step


@pytest.fixture()
def tiny():
    cfg = reduced_config(get_config("h2o-danube-1.8b"))
    model = build_model(cfg)
    return cfg, model


def _pipeline(cfg, batch=4, seq=32):
    return SyntheticTokenPipeline(cfg, global_batch=batch, seq_len=seq)


def test_loss_decreases(tiny, tmp_path):
    cfg, model = tiny
    step_fn = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                                 total_steps=40))
    state = init_train_state(model, jax.random.PRNGKey(0))
    res = run_training(step_fn, state, _pipeline(cfg),
                       LoopConfig(total_steps=30, ckpt_every=100,
                                  ckpt_dir=str(tmp_path)))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.05, (first, last)


def test_checkpoint_resume_bitexact(tiny, tmp_path):
    """20 straight steps == 10 steps + restart + 10 steps (same data)."""
    cfg, model = tiny
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = make_train_step(model, opt)

    sA = init_train_state(model, jax.random.PRNGKey(0))
    resA = run_training(step_fn, sA, _pipeline(cfg),
                        LoopConfig(total_steps=20, ckpt_every=100,
                                   ckpt_dir=str(tmp_path / "a")))

    sB = init_train_state(model, jax.random.PRNGKey(0))
    run_training(step_fn, sB, _pipeline(cfg),
                 LoopConfig(total_steps=10, ckpt_every=10,
                            ckpt_dir=str(tmp_path / "b")))
    sB2 = init_train_state(model, jax.random.PRNGKey(0))   # fresh process
    resB = run_training(step_fn, sB2, _pipeline(cfg),
                        LoopConfig(total_steps=20, ckpt_every=10,
                                   ckpt_dir=str(tmp_path / "b")))
    assert resB.resumed_from == 10
    for a, b in zip(jax.tree.leaves(resA.state.params),
                    jax.tree.leaves(resB.state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0, rtol=0)


def test_injected_failure_recovers(tiny, tmp_path):
    cfg, model = tiny
    step_fn = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=30))
    state = init_train_state(model, jax.random.PRNGKey(0))
    fired = {"n": 0}

    def fail_once(step):
        if step == 15 and fired["n"] == 0:
            fired["n"] += 1
            return True
        return False

    res = run_training(step_fn, state, _pipeline(cfg),
                       LoopConfig(total_steps=20, ckpt_every=5,
                                  ckpt_dir=str(tmp_path)),
                       failure_fn=fail_once)
    assert res.rollbacks == 1
    assert int(res.state.step) == 20


def test_failure_before_checkpoint_raises(tiny, tmp_path):
    cfg, model = tiny
    step_fn = make_train_step(model, AdamWConfig())
    state = init_train_state(model, jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError):
        run_training(step_fn, state, _pipeline(cfg),
                     LoopConfig(total_steps=10, ckpt_every=50,
                                ckpt_dir=str(tmp_path)),
                     failure_fn=lambda s: s == 3)


def test_checkpoint_atomicity(tiny, tmp_path):
    """Interrupted (partial) checkpoint directories are never listed."""
    cfg, model = tiny
    state = init_train_state(model, jax.random.PRNGKey(0))
    ckpt_lib.save_checkpoint(str(tmp_path), 5, state)
    # fake a torn write: tmp dir left behind
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt_lib.list_checkpoints(str(tmp_path)) == [5]


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=0.05)
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)


def test_bf16_opt_state_dtype(tiny):
    cfg, model = tiny
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, "bfloat16")
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(opt["m"]))
    g = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    new_p, new_opt, _ = adamw_update(
        AdamWConfig(state_dtype="bfloat16"), params, g, opt)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(new_opt["v"]))


def test_grad_compression_train_step_runs(tiny):
    """shard_map cross-pod compression path traces and runs on a 1-'pod'
    mesh (numerical path identical to DP mean when pods=1)."""
    cfg, model = tiny
    mesh = jax.make_mesh((1,), ("pod",))
    opt = AdamWConfig(lr=1e-3)
    step_fn = make_train_step(model, opt, compress_pods=True, mesh=mesh)
    state = init_train_state(model, jax.random.PRNGKey(0), n_pods=1)
    batch = _pipeline(cfg).get_batch(0)
    batch = jax.tree.map(jnp.asarray, batch)
    with mesh:
        new_state, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
