"""Design-space exploration: pick an HBM-CO SKU and RPU scale for YOUR
model and latency/power target (the paper's §VII/§VIII methodology as a
tool).

  PYTHONPATH=src python examples/design_space.py --arch llama3-70b \
      --target-ms 0.5 --tdp-w 1000
"""
import argparse

from repro.configs import get_config, list_configs
from repro.core.hbmco import enumerate_design_space, pareto_frontier
from repro.models.footprint import compute_footprint
from repro.sim.scaling import (cu_tdp_w, min_cus_for_model, rpu_point,
                               select_sku_for)
from repro.core import hardware


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-70b", choices=list_configs())
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8192)
    ap.add_argument("--target-ms", type=float, default=None)
    ap.add_argument("--tdp-w", type=float, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    fp = compute_footprint(cfg)
    print(f"model {cfg.name}: {fp.total_params/1e9:.1f}B params "
          f"({fp.active_params/1e9:.1f}B active), "
          f"KV$ {fp.kv_bytes(args.batch, args.seq)/1e9:.2f} GB "
          f"at b={args.batch} s={args.seq}")

    print("\nfrontier SKUs:", ", ".join(
        f"{c.capacity_mb:.0f}MB/{c.energy_pj_per_bit:.2f}pJ"
        for c in pareto_frontier(enumerate_design_space())))

    n_min = min_cus_for_model(cfg, batch=args.batch, seq_len=args.seq)
    print(f"\n{'CUs':>6} {'SKU':>16} {'BW/Cap':>7} {'ms/tok':>8} "
          f"{'TDP W':>8} {'J/tok':>7} {'cost':>7}")
    chosen = None
    n = max(n_min, 8)
    while n <= 1024:
        p = rpu_point(cfg, n, batch=args.batch, seq_len=args.seq)
        if p is not None:
            print(f"{n:6d} {p.sku.name:>16} {p.sku.bw_per_cap:7.0f} "
                  f"{p.ms_per_token:8.3f} {p.tdp_w:8.0f} "
                  f"{p.sim.energy_j:7.2f} {p.cost:7.2f}")
            ok_lat = args.target_ms is None or p.ms_per_token <= args.target_ms
            ok_tdp = args.tdp_w is None or p.tdp_w <= args.tdp_w
            if ok_lat and ok_tdp and chosen is None:
                chosen = p
        n *= 2

    if args.target_ms or args.tdp_w:
        if chosen:
            print(f"\n==> pick {chosen.n_cus} CUs with {chosen.sku.name}: "
                  f"{chosen.ms_per_token:.3f} ms/tok at {chosen.tdp_w:.0f} W")
        else:
            print("\n==> no configuration meets the constraints; "
                  "relax --target-ms / raise --tdp-w")


if __name__ == "__main__":
    main()
