"""Llama4-Scout 109B-A17B (paper simulator baseline): 16 experts top-1,
MoE every layer, one shared expert."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-109b-a17b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=202048, vocab_pad_multiple=512,
    moe=True, n_experts=16, n_experts_per_token=1, n_shared_experts=1,
    moe_d_ff=8192, moe_layer_period=1, rope_theta=500000.0,
)
