"""Paper Fig 14: speculative-decoding comparison (Llama3-70B target,
Llama3-8B draft, 8-token lookahead, 4.6 accepted/window, 1.8x)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.scaling import rpu_point

PUBLISHED_TOKENS_PER_S = {
    "NVIDIA H200": 134, "SambaNova": 457, "Groq LPU": 1678,
    "Cerebras WSE-3": 2148, "RPU (paper)": 4423,
}


def run() -> list[Row]:
    cfg70 = get_config("llama3-70b")
    cfg8 = get_config("llama3-8b")
    # RPU-200CU base decode latency for the 70B target + 8B draft steps.
    p70 = rpu_point(cfg70, 200, batch=1, seq_len=8192)
    p8 = rpu_point(cfg8, 200, batch=1, seq_len=8192)
    gamma, accepted = 8, 4.6                      # paper's window stats
    # one window: gamma draft steps + 1 target verification pass (the
    # verification VMM streams the same weights once — like one target step)
    window_s = gamma * p8.ms_per_token * 1e-3 + p70.ms_per_token * 1e-3
    toks_per_s = accepted / window_s
    base_tps = 1e3 / p70.ms_per_token
    rows = [
        Row("Fig14", "RPU-200CU 70B base decode", base_tps, None, " tok/s"),
        Row("Fig14", "RPU-200CU speculative throughput", toks_per_s, 4423,
            " tok/s", f"{gamma}-lookahead, {accepted} accepted"),
        Row("Fig14", "speculative speedup", toks_per_s / base_tps, 1.8, "x"),
    ]
    for sys_name, tps in PUBLISHED_TOKENS_PER_S.items():
        rows.append(Row("Fig14", f"published: {sys_name}", tps, None,
                        " tok/s"))
    rows.append(Row("Fig14", "RPU(ours)/best-competitor",
                    toks_per_s / 2148, 4423 / 2148, "x", "vs Cerebras WSE-3"))
    return rows
