"""Request-level generation API: SamplingParams, the fused per-slot
sampler, determinism invariants (slot permutation / preemption-restart /
static-vs-continuous), the no-recompile guarantee, finish reasons,
streaming outputs, and the LLMEngine façade over all three backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.model import build_model
from repro.runtime import sampling
from repro.runtime.engine import ContinuousServeEngine, ServeEngine
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# SamplingParams + standalone helpers
# ---------------------------------------------------------------------------


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(min_p=1.0)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    sp = SamplingParams(stop_token_ids=[3, np.int32(7)])
    assert sp.stop_token_ids == (3, 7)
    assert sp.is_greedy and not SamplingParams(temperature=0.5).is_greedy


def test_sample_top_p_restricts_support():
    lg = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    for i in range(30):
        t = sampling.sample(jax.random.fold_in(jax.random.PRNGKey(1), i),
                            lg, 1.0, 0, 0.6)
        assert int(t[0]) in (0, 1)            # nucleus = {0.5, 0.3}
    # top_p=1.0 eventually reaches the tail
    seen = {int(sampling.sample(jax.random.fold_in(jax.random.PRNGKey(2), i),
                                lg, 1.0)[0]) for i in range(200)}
    assert len(seen) > 2


def test_sample_min_p_restricts_support():
    lg = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    for i in range(30):
        t = sampling.sample(jax.random.fold_in(jax.random.PRNGKey(3), i),
                            lg, 1.0, 0, 1.0, 0.4)
        assert int(t[0]) in (0, 1)            # floor = 0.4 * 0.5 = 0.2


def test_sample_slots_greedy_rows_match_argmax():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (5, 97))
    temp, topk, topp, minp, seed = sampling.stack_params(
        [sampling.GREEDY] * 5)
    tok, lp = sampling.sample_slots(logits, jnp.asarray(temp),
                                    jnp.asarray(topk), jnp.asarray(topp),
                                    jnp.asarray(minp), jnp.asarray(seed),
                                    jnp.zeros((5,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.asarray(jnp.argmax(logits, -1)))
    ref_lp = jax.nn.log_softmax(logits, -1)[jnp.arange(5), tok]
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ref_lp), rtol=1e-5)


def test_sample_slots_row_permutation_invariant():
    """The sampler is per-row: permuting rows permutes tokens — the device
    half of the slot-assignment determinism invariant."""
    logits = jax.random.normal(jax.random.PRNGKey(4), (6, 64))
    args = sampling.stack_params(
        [SamplingParams(temperature=0.9, top_k=7, top_p=0.9, seed=i)
         for i in range(6)])
    pos = np.arange(10, 16, dtype=np.int32)
    tok, lp = sampling.sample_slots(
        logits, *(jnp.asarray(a) for a in args), jnp.asarray(pos))
    perm = np.asarray([3, 0, 5, 1, 4, 2])
    tok2, lp2 = sampling.sample_slots(
        jnp.asarray(np.asarray(logits)[perm]),
        *(jnp.asarray(np.asarray(a)[perm]) for a in args),
        jnp.asarray(pos[perm]))
    np.testing.assert_array_equal(np.asarray(tok)[perm], np.asarray(tok2))
    np.testing.assert_array_equal(np.asarray(lp)[perm], np.asarray(lp2))


def test_sample_slots_topk_topp_support():
    p = jnp.asarray([[0.4, 0.3, 0.2, 0.05, 0.05]])
    lg = jnp.log(jnp.tile(p, (32, 1)))
    # top_k=3 cuts {3,4}; top_p=0.5 then cuts index 2 (0.4+0.3 >= 0.5)
    args = sampling.stack_params(
        [SamplingParams(temperature=1.0, top_k=3, top_p=0.5, seed=s)
         for s in range(32)])
    tok, _ = sampling.sample_slots(lg, *(jnp.asarray(a) for a in args),
                                   jnp.arange(32, dtype=jnp.int32))
    assert set(np.asarray(tok).tolist()) <= {0, 1}


# ---------------------------------------------------------------------------
# Engine determinism invariants (the tentpole's acceptance criteria)
# ---------------------------------------------------------------------------


SP = [SamplingParams(temperature=0.8, top_k=8, top_p=0.95, seed=100 + i)
      for i in range(4)]


def _reqs(toks, order, G=8):
    return [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G,
                    sampling=SP[i]) for i in order]


@pytest.fixture(scope="module")
def sampled_runs(small):
    """One reference sampled run shared by the determinism tests."""
    cfg, model, params = small
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                         cfg.vocab_size))
    eng = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                num_pages=64, max_len=21)
    ref = eng.run(_reqs(toks, [0, 1, 2, 3]))
    return toks, eng, ref


def test_sampled_deterministic_across_slot_assignments(sampled_runs):
    """Same seeds, submission order reversed => different rid->slot map,
    byte-identical tokens per request."""
    toks, eng, ref = sampled_runs
    out = eng.run(_reqs(toks, [3, 2, 1, 0]))
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], out.results[i])


def test_sampled_deterministic_across_forced_preemption(small, sampled_runs):
    """A page pool tight enough to force eviction/restart must re-emit the
    same sampled tokens (fold_in(seed, pos) streams)."""
    cfg, model, params = small
    toks, _, ref = sampled_runs
    tight = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                  num_pages=12, max_len=21)
    out = tight.run(_reqs(toks, [0, 1, 2, 3]))
    assert out.preemptions > 0                 # pressure was real
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], out.results[i])


def test_sampled_static_matches_continuous_batch1(small, sampled_runs):
    cfg, model, params = small
    toks, eng, _ = sampled_runs
    seng = ServeEngine(model, params, max_len=21, donate_cache=False)
    st = seng.generate({"tokens": jnp.asarray(toks[:1])}, max_new_tokens=8,
                       sampling_params=SP[0])
    ct = eng.run(_reqs(toks, [0]))
    np.testing.assert_array_equal(np.asarray(st.tokens[0]), ct.results[0])


def test_changing_sampling_params_never_recompiles(small, sampled_runs):
    """One decode-step jit signature serves any greedy/sampled mix."""
    cfg, model, params = small
    toks, eng, _ = sampled_runs
    n_step = eng._step_fn._cache_size()
    n_chunk = eng._chunk._cache_size()
    mix = [SamplingParams(),                          # greedy
           SamplingParams(temperature=1.3, top_p=0.8, seed=1),
           SamplingParams(temperature=0.4, top_k=2, min_p=0.2, seed=2),
           SamplingParams(temperature=1.0, top_k=5, top_p=0.7, seed=3,
                          stop_token_ids=(1, 2), logprobs=True)]
    eng.run([Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=6,
                     sampling=mix[i]) for i in range(4)])
    assert eng._step_fn._cache_size() == n_step
    assert eng._chunk._cache_size() == n_chunk


def test_seed_changes_output_temperature_zero_does_not(small, sampled_runs):
    toks, eng, _ = sampled_runs
    base = SamplingParams(temperature=1.2, top_p=0.98, seed=5)
    runs = {}
    for seed in (5, 5, 6):
        out = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                               max_new_tokens=8,
                               sampling=dataclasses.replace(base, seed=seed))])
        runs.setdefault(seed, []).append(out.results[0])
    np.testing.assert_array_equal(runs[5][0], runs[5][1])   # reproducible
    assert not np.array_equal(runs[5][0], runs[6][0])       # seed matters


# ---------------------------------------------------------------------------
# Finish reasons, streaming, logprobs
# ---------------------------------------------------------------------------


def test_stop_token_finishes_early_with_reason(small, sampled_runs):
    cfg, model, params = small
    toks, eng, ref = sampled_runs
    # pick the 3rd token of a greedy run as the stop token
    greedy = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                              max_new_tokens=8)])
    stop = int(greedy.results[0][2])
    out = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                           max_new_tokens=8,
                           sampling=SamplingParams(stop_token_ids=(stop,)))])
    o = out.outputs[0]
    assert o.finish_reason == "stop"
    assert o.token_ids[-1] == stop and len(o.token_ids) == 3
    assert out.outputs[0].finished


def test_max_tokens_reason_and_sampling_max_tokens_cap(small, sampled_runs):
    toks, eng, _ = sampled_runs
    out = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                           max_new_tokens=8,
                           sampling=SamplingParams(max_tokens=4))])
    o = out.outputs[0]
    assert o.finish_reason == "length" and len(o.token_ids) == 4


def test_streaming_deltas_no_duplicates_across_preemption(small):
    """Concatenated streamed deltas == final tokens, exactly once per
    token, even when preemption restarts regeneration."""
    cfg, model, params = small
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (4, 12), 0,
                                         cfg.vocab_size))
    tight = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                  num_pages=12, max_len=21)
    seen: dict[int, list[int]] = {i: [] for i in range(4)}
    finished = set()

    def on_output(o):
        seen[o.rid].extend(o.new_token_ids)
        if o.finished:
            finished.add(o.rid)

    stats = tight.run(_reqs(toks, [0, 1, 2, 3]), on_output=on_output)
    assert stats.preemptions > 0
    assert finished == {0, 1, 2, 3}
    for i in range(4):
        assert seen[i] == stats.results[i].tolist()


def test_incremental_add_request_step_interface(small):
    cfg, model, params = small
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (2, 8), 0,
                                         cfg.vocab_size))
    llm = LLMEngine(model, params, backend="continuous", max_len=17,
                    num_slots=2, page_size=4)
    r0 = llm.add_request(toks[0], SamplingParams(max_tokens=5))
    r1 = llm.add_request(toks[1], SamplingParams(temperature=0.7, seed=3,
                                                 max_tokens=5))
    got: dict[int, list[int]] = {r0: [], r1: []}
    while llm.has_unfinished():
        for o in llm.step():
            got[o.rid].extend(o.new_token_ids)
    assert len(got[r0]) == 5 and len(got[r1]) == 5
    # greedy request must equal the one-shot API's result
    ref = llm.generate([toks[0]], SamplingParams(max_tokens=5))
    assert got[r0] == ref[0].token_ids
    # generate() must refuse to clobber in-flight incremental requests
    llm.add_request(toks[0], SamplingParams(max_tokens=3))
    with pytest.raises(RuntimeError, match="unfinished"):
        llm.generate([toks[1]], SamplingParams(max_tokens=3))
    while llm.has_unfinished():
        llm.step()


def test_request_logprobs_returned_and_consistent(small, sampled_runs):
    cfg, model, params = small
    toks, eng, _ = sampled_runs
    out = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                           max_new_tokens=6,
                           sampling=SamplingParams(logprobs=True))])
    o = out.outputs[0]
    assert o.logprobs is not None and len(o.logprobs) == 6
    assert all(lp <= 0.0 for lp in o.logprobs)
    # greedy chose the argmax, so its logprob is the row max
    seng = ServeEngine(model, params, max_len=21, donate_cache=False)
    st = seng.generate({"tokens": jnp.asarray(toks[:1])}, max_new_tokens=6,
                       sampling_params=SamplingParams(logprobs=True))
    np.testing.assert_allclose(np.asarray(st.logprobs[0]),
                               np.asarray(o.logprobs), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# LLMEngine façade
# ---------------------------------------------------------------------------


def test_llm_engine_greedy_identical_across_backends(small):
    cfg, model, params = small
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (10,), 0,
                                           cfg.vocab_size))
    outs = {}
    for backend in ("static", "continuous", "speculative"):
        llm = LLMEngine(model, params, backend=backend, max_len=32,
                        num_slots=2, page_size=4, gamma=4)
        outs[backend] = llm.generate([prompt], max_new_tokens=6)[0]
    assert (outs["static"].token_ids == outs["continuous"].token_ids
            == outs["speculative"].token_ids)
    assert all(o.finished and o.finish_reason == "length"
               for o in outs.values())
    assert outs["speculative"].metrics["accepted_per_window"] >= 3.9


def test_llm_engine_per_request_mix_and_stop(small):
    cfg, model, params = small
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(10), (3, 8),
                                            0, cfg.vocab_size))
    llm = LLMEngine(model, params, backend="continuous", max_len=20,
                    num_slots=2, page_size=4)
    greedy = llm.generate([prompts[0]], max_new_tokens=6)[0]
    stop = greedy.token_ids[1]
    mix = [SamplingParams(),
           SamplingParams(temperature=0.9, top_p=0.9, seed=4),
           SamplingParams(stop_token_ids=(stop,))]
    outs = llm.generate(list(prompts), mix, max_new_tokens=6)
    assert outs[0].token_ids == greedy.token_ids
    assert outs[2].finish_reason == "stop" if prompts[2].tolist() == \
        prompts[0].tolist() else outs[2].finish_reason in ("stop", "length")
    assert [o.rid for o in outs] == [0, 1, 2]


def test_llm_engine_static_requires_uniform_lengths(small):
    cfg, model, params = small
    llm = LLMEngine(model, params, backend="static", max_len=32)
    with pytest.raises(ValueError, match="one prompt length"):
        llm.generate([np.zeros(4, np.int32), np.zeros(6, np.int32)],
                     max_new_tokens=4)


def test_llm_engine_validation(small):
    cfg, model, params = small
    with pytest.raises(ValueError, match="backend"):
        LLMEngine(model, params, backend="magic")
    llm = LLMEngine(model, params, backend="continuous", max_len=16,
                    num_slots=2, page_size=4)
    with pytest.raises(ValueError, match="max_tokens"):
        llm.generate([np.zeros(4, np.int32)])
    with pytest.raises(ValueError, match="max_len"):
        llm.generate([np.zeros(4, np.int32)], max_new_tokens=100)
    with pytest.raises(ValueError, match="max_top_k"):
        llm.generate([np.zeros(4, np.int32)],
                     SamplingParams(top_k=sampling.MAX_TOP_K + 1,
                                    max_tokens=4))


def test_legacy_engine_kwargs_removed(small):
    """The one-release ``temperature=``/``top_k=`` deprecation shim is
    gone: the kwargs are rejected outright."""
    cfg, model, params = small
    with pytest.raises(TypeError):
        ServeEngine(model, params, max_len=20, temperature=0.7, top_k=4)
    with pytest.raises(TypeError):
        ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                              num_pages=16, max_len=16, temperature=0.5)


def test_speculative_compilations_cached_across_prompts(small):
    """Repeated speculative prompts reuse the engine-held jits: one window
    per SamplingParams filter config, one target/draft prefill each —
    re-prompting stops re-tracing (ROADMAP follow-on)."""
    cfg, model, params = small
    llm = LLMEngine(model, params, backend="speculative", max_len=40,
                    gamma=4)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(13), (3, 8),
                                            0, cfg.vocab_size))
    sp = SamplingParams(temperature=0.8, top_k=8, seed=1)
    llm.generate([prompts[0]], sp, max_new_tokens=4)
    spec = llm._spec
    assert len(spec._windows) == 1
    win = next(iter(spec._windows.values()))
    n_win = win._cache_size()
    n_pre = spec._prefill_t._cache_size()
    # same shapes + same filter config: zero new traces anywhere
    llm.generate([prompts[1], prompts[2]],
                 [dataclasses.replace(sp, seed=7),
                  dataclasses.replace(sp, seed=9)], max_new_tokens=4)
    assert len(spec._windows) == 1
    assert win._cache_size() == n_win
    assert spec._prefill_t._cache_size() == n_pre
    # a different filter config compiles ONE new window, prefills reused
    llm.generate([prompts[0]], SamplingParams(temperature=1.2, top_p=0.9),
                 max_new_tokens=4)
    assert len(spec._windows) == 2
    assert spec._prefill_t._cache_size() == n_pre


def test_speculative_acceptance_under_sampled_params(small):
    """Identical draft/target with per-request sampling params: every
    proposal is drawn from and verified against the SAME filtered
    distribution, so acceptance stays ~perfect."""
    cfg, model, params = small
    from repro.runtime.speculative import speculative_generate
    prompt = jax.random.randint(jax.random.PRNGKey(12), (1, 8), 0,
                                cfg.vocab_size)
    stats = speculative_generate(
        model, params, model, params, prompt, max_new_tokens=8, gamma=4,
        sampling_params=SamplingParams(temperature=0.8, top_k=8, top_p=0.9,
                                       seed=2))
    assert float(stats.accepted_per_window.mean()) >= 3.9


# ---------------------------------------------------------------------------
# Per-slot logit processors: logit_bias + repetition_penalty (data arrays)
# ---------------------------------------------------------------------------


def test_sampling_params_processor_validation():
    with pytest.raises(ValueError):
        SamplingParams(repetition_penalty=0.0)
    with pytest.raises(ValueError):            # over the static budget
        SamplingParams(logit_bias={i: 1.0 for i in range(
            sampling.MAX_LOGIT_BIAS + 1)})
    with pytest.raises(ValueError):
        SamplingParams(logit_bias={-1: 1.0})
    sp = SamplingParams(logit_bias={3: 1.5})   # dicts normalize to pairs
    assert sp.logit_bias == ((3, 1.5),)


def test_sample_slots_logit_bias_forces_and_blocks():
    lg = jnp.tile(jnp.log(jnp.asarray([[0.7, 0.2, 0.05, 0.05]])), (4, 1))
    greedy4 = [SamplingParams()] * 4
    args = [jnp.asarray(a) for a in sampling.stack_params(greedy4)]
    pos = jnp.zeros((4,), jnp.int32)
    # +30 on token 2 dominates; -1e9 on the argmax demotes it
    force = [SamplingParams(logit_bias={2: 30.0})] * 4
    rep, bids, bvals = (jnp.asarray(a) for a in sampling.stack_extras(force))
    tok, _ = sampling.sample_slots(lg, *args, pos, rep_penalty=rep,
                                   bias_ids=bids, bias_vals=bvals)
    assert np.asarray(tok).tolist() == [2, 2, 2, 2]
    block = [SamplingParams(logit_bias={0: -1e9})] * 4
    rep, bids, bvals = (jnp.asarray(a) for a in sampling.stack_extras(block))
    tok, _ = sampling.sample_slots(lg, *args, pos, rep_penalty=rep,
                                   bias_ids=bids, bias_vals=bvals)
    assert np.asarray(tok).tolist() == [1, 1, 1, 1]


def test_sample_slots_repetition_penalty_discourages_seen():
    # positive-logit branch: seen argmax divides below the runner-up
    lg = jnp.tile(jnp.asarray([[2.0, 1.5, 0.1, 0.0]]), (2, 1))
    pres = jnp.asarray([[True, False, False, False],
                        [False, False, False, False]])
    sps = [SamplingParams(repetition_penalty=2.0)] * 2
    args = [jnp.asarray(a) for a in sampling.stack_params(sps)]
    rep, bids, bvals = (jnp.asarray(a) for a in sampling.stack_extras(sps))
    tok, _ = sampling.sample_slots(lg, *args, jnp.zeros((2,), jnp.int32),
                                   rep_penalty=rep, bias_ids=bids,
                                   bias_vals=bvals, presence=pres)
    assert np.asarray(tok).tolist() == [1, 0]      # only the seen row moves
    # negative-logit branch: seen logits multiply (further from zero)
    lgn = jnp.asarray([[-0.6, -1.0, -3.0, -3.0]])
    tok, _ = sampling.sample_slots(
        lgn, *(a[:1] for a in args), jnp.zeros((1,), jnp.int32),
        rep_penalty=rep[:1], bias_ids=bids[:1], bias_vals=bvals[:1],
        presence=jnp.asarray([[True, False, False, False]]))
    assert np.asarray(tok).tolist() == [1]


PROC_MIX = [
    SamplingParams(repetition_penalty=1.8),                    # greedy + rp
    SamplingParams(temperature=0.9, top_k=8, top_p=0.95, seed=107,
                   repetition_penalty=1.3, logit_bias={5: 2.0}),
    SamplingParams(logit_bias={3: 30.0, 7: -30.0}),            # forced bias
    SamplingParams(temperature=1.1, seed=42),                  # plain sample
]


def _proc_reqs(toks, order, G=8):
    return [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G,
                    sampling=PROC_MIX[i]) for i in order]


def test_processors_static_matches_continuous_through_preemption(small):
    """Penalized/biased streams are byte-identical across the static scan,
    the roomy continuous engine, and a tight pool that forces
    preemption-restarts (presence rebuilds deterministically)."""
    cfg, model, params = small
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (4, 12), 0,
                                         cfg.vocab_size))
    roomy = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                  num_pages=64, max_len=21)
    ref = roomy.run(_proc_reqs(toks, [0, 1, 2, 3]))
    # the repetition penalty actually bites: the greedy+rp stream differs
    # from the plain-greedy stream for the same prompt
    plain = roomy.run([Request(rid=0, prompt=np.asarray(toks[0]),
                               max_new_tokens=8,
                               sampling=SamplingParams())])
    assert not np.array_equal(ref.results[0], plain.results[0])
    # forced bias dominates every draw
    assert np.asarray(ref.results[2]).tolist() == [3] * 8
    tight = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                  num_pages=12, max_len=21)
    out = tight.run(_proc_reqs(toks, [0, 1, 2, 3]))
    assert out.preemptions > 0
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], out.results[i])
    seng = ServeEngine(model, params, max_len=21, donate_cache=False)
    st = seng.generate({"tokens": jnp.asarray(toks)}, max_new_tokens=8,
                       sampling_params=PROC_MIX)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(st.tokens[i]),
                                      ref.results[i])


def test_processor_mix_never_recompiles(small, sampled_runs):
    """logit_bias / repetition_penalty are per-slot data: serving a mix of
    penalized, biased, and plain requests reuses the compiled step."""
    cfg, model, params = small
    toks, eng, _ = sampled_runs
    n_step = eng._step_fn._cache_size()
    n_chunk = eng._chunk._cache_size()
    eng.run(_proc_reqs(toks, [0, 1, 2, 3]))
    assert eng._step_fn._cache_size() == n_step
    assert eng._chunk._cache_size() == n_chunk


def test_speculative_backend_rejects_processors(small):
    cfg, model, params = small
    llm = LLMEngine(model, params, backend="speculative", max_len=32)
    with pytest.raises(ValueError, match="repetition_penalty"):
        llm.generate([np.arange(8)],
                     SamplingParams(repetition_penalty=1.2),
                     max_new_tokens=4)
