"""Shared model substrate: config, initializers, norms, RoPE, attention.

Everything is pure-functional JAX over explicit parameter pytrees.  Layer
stacks are stored with a leading layer axis and executed with
``jax.lax.scan`` so the lowered HLO is O(1) in depth (essential for the
CPU dry-run of 40-48 layer configs, and the production-correct choice).

Attention is implemented **blocked** (flash-style online softmax over KV
blocks in pure ``lax``), so prefill at 32k context never materializes an
(S x S) score matrix — the JAX analogue of the paper's KV$-streaming SDPA
phase.  The Pallas decode kernel in ``kernels/decode_attention`` is the
TPU-optimized version of the decode path; the functions here are the
reference implementations used for training, prefill, and CPU tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 512

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int | None = None   # SWA window (tokens)
    global_attn_every: int = 0          # hybrid SWA/global interleave (0=never)
    rope_theta: float = 10000.0
    causal: bool = True                 # False => encoder-only

    # MLA (DeepSeek)
    mla: bool = False
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    v_head_dim: int = 0                 # 0 -> head_dim

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    moe_layer_period: int = 1           # 1 = every layer is MoE

    # SSM (Mamba2 SSD)
    ssm: bool = False
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    hybrid: bool = False                # Hymba: parallel attn + ssm heads

    # modality frontends (stubs; embeddings come via input_specs)
    frontend: str | None = None         # "audio" | "vision"
    n_frontend_tokens: int = 0          # e.g. image tokens prepended

    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # Vocab padding (Megatron-style): embedding/head tables are padded to a
    # multiple so they shard evenly over any TP degree in the mesh zoo
    # (16-way model TP and the 512-way multi-pod ring).  Padded logit
    # columns are masked to -inf in the head.  1 = no padding (smoke tests).
    vocab_pad_multiple: int = 1

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def v_hd(self) -> int:
        return self.v_head_dim or self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def attn_impl_window(self) -> int | None:
        return self.sliding_window

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.moe:
            return False
        if layer_idx < self.first_dense_layers:
            return False
        return (layer_idx - self.first_dense_layers) % self.moe_layer_period == 0

    @property
    def has_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None


PARAM_DTYPE = jnp.bfloat16
NORM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=PARAM_DTYPE) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=PARAM_DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms & activations (HP-VOPs analogue: fp32 internals)
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, w: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """Mamba2 norm: RMSNorm(x * silu(z))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    from repro.parallel.hints import tp_row_dot
    from repro.quant.linear import qdot
    g = qdot(x, w_gate)
    u = qdot(x, w_up)
    return tp_row_dot(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                      w_down)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) split-half convention; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (B, S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention in pure lax — the KV$-streaming SDPA
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_expand(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KVH, D) -> (B, S, H, D) by repeating groups."""
    b, s, kvh, d = k.shape
    rep = n_heads // kvh
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def blocked_attention(
    q: jnp.ndarray,              # (B, Sq, H, D)
    k: jnp.ndarray,              # (B, Skv, KVH, D)
    v: jnp.ndarray,              # (B, Skv, KVH, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jnp.ndarray = 0,
    q_block: int = 512,
    kv_block: int = 512,
    scale: float | None = None,
) -> jnp.ndarray:
    """Online-softmax attention over KV blocks; never builds (Sq x Skv).

    ``q_offset`` is the absolute position of q[:, 0] (for prefill
    continuation / decode): a scalar, or a ``(B,)`` array for ragged
    continuation (chunked paged prefill, where every slot resumes at its
    own position).  fp32 softmax state (HP-VOPs analogue).
    """
    b, sq, h, d = q.shape
    per_row = getattr(q_offset, "ndim", 0) == 1
    _, skv, kvh, dv = v.shape[0], v.shape[1], v.shape[2], v.shape[3]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # pad sequences up to block multiples
    sq_p = -(-sq // qb) * qb
    skv_p = -(-skv // kb) * kb
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))

    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)

    nq, nk = sq_p // qb, skv_p // kb
    qr = q.reshape(b, nq, qb, h, d).astype(jnp.float32)
    kr = k.reshape(b, nk, kb, h, d).astype(jnp.float32)
    vr = v.reshape(b, nk, kb, h, dv).astype(jnp.float32)

    if per_row:
        # (B, nq, qb) absolute positions, one offset per batch row
        q_pos = (jnp.asarray(q_offset, jnp.int32)[:, None, None]
                 + jnp.arange(sq_p).reshape(nq, qb)[None])
    else:
        q_pos = q_offset + jnp.arange(sq_p).reshape(nq, qb)
    k_pos = jnp.arange(skv_p).reshape(nk, kb)
    kv_valid = (jnp.arange(skv_p) < skv).reshape(nk, kb)

    def q_block_fn(qi, q_blk):
        # q_blk: (B, qb, H, D); scan over kv blocks
        qp = q_pos[:, qi] if per_row else q_pos[qi]        # (B, qb) | (qb,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            kp = k_pos[kj]                                 # (kb,)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk) * scale
            if per_row:
                mask = kv_valid[kj][None, None, :]         # (1, 1, kb)
                if causal:
                    mask = mask & (kp[None, None, :] <= qp[:, :, None])
                if window is not None:
                    mask = mask & (qp[:, :, None] - kp[None, None, :] < window)
                s = jnp.where(mask[:, None], s, NEG_INF)   # (B, 1, qb, kb)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = (acc * corr[..., None]
                           + jnp.einsum("bhqk,bkhd->bhqd", p, v_blk))
                return (m_new, l_new, acc_new), None
            mask = kv_valid[kj][None, :]                   # (1, kb)
            if causal:
                mask = mask & (kp[None, :] <= qp[:, None])
            if window is not None:
                mask = mask & (qp[:, None] - kp[None, :] < window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qb), jnp.float32)
        a0 = jnp.zeros((b, h, qb, dv), jnp.float32)
        ks = jnp.arange(nk)
        # checkpoint the kv step: the backward pass recomputes each block's
        # probability matrix instead of saving all nk of them — the
        # flash-attention memory contract ((B,H,qb,kb) x nk would dominate
        # training HBM at 4k x 256 x 40L).
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (ks, jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.transpose(out, (0, 2, 1, 3))            # (B, qb, H, Dv)

    # checkpoint per q-block as well: the backward otherwise stacks every
    # block's (m, l, acc) kv-scan carries (nq x nk x (B,H,qb,dv) f32).
    outs = jax.lax.map(jax.checkpoint(
        lambda args: q_block_fn(args[0], args[1])),
        (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, h, dv)[:, :sq]
    return out.astype(q.dtype)


def cache_update_at(cache_arr: jnp.ndarray, new: jnp.ndarray, slot) -> jnp.ndarray:
    """Write one token's entry at dynamic position ``slot`` along axis 1.

    Uses an elementwise select instead of ``dynamic_update_slice``: DUS at
    a dynamic index on a context-sharded (S-partitioned) cache forces
    GSPMD into involuntary full rematerialization — the cache is
    all-gathered, updated, and re-sharded EVERY layer, turning a one-token
    write into a full cache read+write (measured 24x memory-term blowup on
    decode cells; EXPERIMENTS.md §Perf iteration 1).  The select is
    elementwise, so every shard updates locally.

    ``new``: (B, 1, ...) broadcastable against ``cache_arr`` (B, S, ...).
    """
    s = cache_arr.shape[1]
    iota_shape = (1, s) + (1,) * (cache_arr.ndim - 2)
    iota = jax.lax.broadcasted_iota(jnp.int32, iota_shape, 1)
    return jnp.where(iota == slot, new.astype(cache_arr.dtype), cache_arr)


def decode_attention_ref(
    q: jnp.ndarray,              # (B, H, D) — one new token per sequence
    k_cache: jnp.ndarray,        # (B, S, KVH, D)
    v_cache: jnp.ndarray,        # (B, S, KVH, Dv)
    cur_len: jnp.ndarray | None = None,   # (B,) int32 — #valid positions
    *,
    valid: jnp.ndarray | None = None,     # (S,) or (B, S) bool mask
    scale: float | None = None,
) -> jnp.ndarray:
    """Single-token decode attention (pure-jnp oracle for the Pallas kernel).

    Pass either ``cur_len`` (prefix-valid cache) or an explicit ``valid``
    mask (ring-buffer sliding-window caches).
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rep = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, rep, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bgrd,bsgd->bgrs", qf, kf) * scale
    if valid is None:
        valid = jnp.arange(k_cache.shape[1])[None, :] < cur_len[:, None]
    if valid.ndim == 1:
        valid = valid[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, vf)
    return out.reshape(b, h, vf.shape[-1]).astype(q.dtype)


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))
