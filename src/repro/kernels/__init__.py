"""Pallas TPU kernels for the paper's compute hot-spots:
   mxfp4_vmm        — Stream Decoder + TMAC stripe VMM (paper SSV, Fig 7)
   decode_attention — KV$-streaming flash-decode GQA (the memory-bound SDPA phase)
Each has kernel.py (pallas_call + BlockSpec), ops.py (jit'd wrapper), ref.py (jnp oracle)."""
import jax


def on_cpu() -> bool:
    """True when the default backend is CPU — kernels then either take the
    jnp oracle path or run in (slow) interpret mode, depending on the op."""
    return jax.default_backend() == "cpu"
