"""ParallelPlan: maps model parameters / batches / caches onto the mesh.

The production mesh is ``(pod, data, model)`` (multi-pod) or
``(data, model)`` (single pod):

  * DP  — batch over ``(pod, data)``
  * TP  — weight columns/rows + heads over ``model`` (Megatron-style
          column->row pairing so each block needs one reduction, which is
          exactly the paper's column-shard + reduce dichotomy in §IV)
  * EP  — MoE expert axis over ``model``
  * FSDP — for memory-bound cells (training state, 400B-class weights) the
          non-TP dimension of every matrix is additionally sharded over the
          DP axes (ZeRO-3 / GSPMD style); XLA all-gathers per layer inside
          the scan, overlapped with compute.
  * SP  — training activations shard their sequence dim over ``model``
          (Megatron sequence parallelism) so the scan carry fits at 4k x 256.
  * CP  — decode KV caches shard the sequence dim over ``model`` (the
          paper's "KV$ sharded across CUs"); batch shards over DP axes.
  * long-context — when batch=1 (the ``long_500k`` shape) batch sharding
    is impossible, so the plan widens TP over every mesh axis — the
    paper's "scale bandwidth by adding CUs to the ring" move.

Assignment is by parameter-tree path pattern, so it covers every block kind
in the zoo (attention, MLA, MoE, SSM, hybrid) without per-arch tables.
SSM mixer weights keep TP-unsharded columns in the baseline plan (their
concatenated projection layout doesn't column-shard cleanly); see
EXPERIMENTS.md §Perf for the sharded-SSM hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

# parameter name -> (kind) tables ------------------------------------------

_COL_SHARD = {"wq", "wk", "wv", "w_gate", "w_up", "w_uk", "w_uv",
              "head", "in_proj"}
_BIAS_COL = {"bq", "bk", "bv"}
_ROW_SHARD = {"wo", "w_down", "out_proj"}
_REPLICATE = {"ln1", "ln2", "q_norm", "k_norm", "kv_norm", "final_norm",
              "router", "w_dkv", "norm_w", "conv_w", "conv_b", "A_log", "D",
              "dt_bias", "attn_out_norm", "ssm_out_norm"}
_VOCAB_SHARD = {"embed"}

# Per-device HBM the serve/prefill plans are willing to spend on weights
# before turning on FSDP weight sharding (v5e has 16 GiB total).
_WEIGHT_FIT_BYTES = 8 * 2**30


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(f"[{k.idx}]")
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
    return out


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Sharding plan for one (config x shape) cell."""

    mesh: Mesh
    dp: tuple[str, ...]            # axes sharding the batch
    tp: tuple[str, ...] | str      # axes sharding weights/heads
    fsdp: tuple[str, ...] = ()     # axes sharding the non-TP weight dim
    cache_seq: tuple[str, ...] | str | None = None   # axes sharding KV$ seq
    seq_parallel: bool = False     # shard train activations' seq dim over tp
    ep: bool = True                # advertise shard_map expert parallelism
                                   # (False for train: EP under AD crashes
                                   # XLA:CPU's partitioner; see models/moe.py)
    shard_ssm: bool = True         # shard SSM inner dim (False = replicated
                                   # baseline for the §Perf before/after)

    # ---------------- parameters ----------------
    def _param_spec(self, names: list[str], ndim: int, shape) -> P:
        name = names[-1]
        in_moe = any(n in ("moe",) for n in names) and "shared" not in names
        in_ssm = any(n == "ssm" for n in names)
        fsdp = self.fsdp if self.fsdp else None
        lead = max(0, ndim - 2)

        if in_moe and name in ("w_gate", "w_up", "w_down"):
            # experts (L?, E, D, F): shard experts (EP) + FSDP the D dim
            spec: list = [None] * ndim
            spec[lead - 1 if lead >= 1 else 0] = self.tp
            if fsdp:
                spec[ndim - 2] = fsdp
            return P(*spec)
        if in_ssm:
            # the big projections shard over the model axis (w_z/w_x
            # columns = the head dim; out_proj rows); the SSD internals
            # (conv_w, A_log, D, dt_bias, norm_w, w_bc, w_dt) are small
            # and stay replicated.  ``shard_ssm=False`` reproduces the
            # fused-projection baseline (fully replicated SSM — the §Perf
            # hillclimb's "before").
            if name in ("w_z", "w_x"):
                return P(*([None] * (ndim - 2)), fsdp,
                         self.tp if self.shard_ssm else None)
            if name == "out_proj":
                return P(*([None] * (ndim - 2)),
                         self.tp if self.shard_ssm else fsdp,
                         fsdp if self.shard_ssm else None)
            if name in ("w_bc", "w_dt") and fsdp:
                return P(*([None] * (ndim - 2)), fsdp, None)
            return P()
        if name in _BIAS_COL:   # per-layer 1-D bias (possibly layer-stacked)
            return P(*([None] * (ndim - 1)), self.tp)
        if name in _VOCAB_SHARD:
            return P(*([None] * (ndim - 2)), self.tp, fsdp)
        if name in _COL_SHARD:
            if ndim >= 2:
                return P(*([None] * (ndim - 2)), fsdp, self.tp)
            return P(*([None] * (ndim - 1)), self.tp)
        if name in _ROW_SHARD:
            return P(*([None] * (ndim - 2)), self.tp, fsdp)
        return P()

    def param_shardings(self, params) -> Any:
        from repro.parallel.hints import _drop_uneven

        def assign(path, leaf):
            names = _path_names(path)
            sh = NamedSharding(self.mesh,
                               self._param_spec(names, leaf.ndim, leaf.shape))
            # in_shardings require even divisibility; drop axes that don't
            # divide (e.g. 25-head projections on a 16-way model axis).
            return _drop_uneven(sh, leaf.shape)
        return jax.tree_util.tree_map_with_path(assign, params)

    # ---------------- batches ----------------
    def batch_shardings(self, batch: dict) -> dict:
        def assign(leaf):
            if leaf.ndim == 0:
                return NamedSharding(self.mesh, P())
            if not self.dp:
                return NamedSharding(self.mesh, P())
            return NamedSharding(self.mesh,
                                 P(self.dp, *([None] * (leaf.ndim - 1))))
        return jax.tree.map(assign, batch)

    # ---------------- caches ----------------
    def cache_shardings(self, cache) -> Any:
        """KV caches: shard batch over DP and the sequence dim over
        ``cache_seq`` (context parallelism — the paper's KV$-across-CUs);
        SSM states / conv buffers / slot maps stay replicated apart from
        their batch dim (they are small).
        """
        cs = self.cache_seq if self.cache_seq else None

        def assign(path, leaf):
            names = _path_names(path)
            name = names[-1]
            nd = leaf.ndim
            if name == "slot_pos":
                return NamedSharding(self.mesh, P())
            # batch dim position: 0 if unstacked, 1 if layer-stacked.
            # attn k/v: (B,S,KVH,hd) or (L,B,S,KVH,hd); ssm state (B,H,P,N)
            # or (L,B,H,P,N); mla c_kv (B,S,r) / (L,B,S,r); conv (B,K,C)/(L,..)
            if name in ("k", "v", "ssm"):
                bdim = 1 if nd == 5 else 0
            else:
                bdim = 1 if nd == 4 else 0
            spec: list = [None] * nd
            if self.dp:
                spec[bdim] = self.dp
            # sequence dim (only attn k/v and MLA caches have one)
            if cs is not None and name in ("k", "v", "c_kv", "k_rope"):
                sdim = bdim + 1
                if nd > sdim + (1 if name in ("c_kv", "k_rope") else 2) - 1:
                    spec[sdim] = cs
            return NamedSharding(self.mesh, P(*spec))
        return jax.tree_util.tree_map_with_path(assign, cache)

    def rules(self) -> dict:
        """Logical activation rules for ``parallel.hints.shard_hint``.

        Returned as NamedShardings so ``shard_hint`` can drop axes on dims
        that don't divide (25 heads x 16-way TP etc.).
        """
        dp = self.dp if self.dp else None
        sp = self.tp if self.seq_parallel else None
        specs = {
            "act_bsd": P(dp, sp, None),
            "act_bd": P(dp, None),
            "act_bshd": P(dp, None, self.tp, None),
            "act_bskd": P(dp, None, None, None),
            "logits": P(dp, None, self.tp),
            "logits_bv": P(dp, self.tp),
            # MoE dispatch intermediates: capacity axis / token streams
            # shard over DP (the expert axis is handled by the EP
            # shard_map; 'model' would be invalid inside its manual region)
            "moe_ecd": P(None, dp, None),
            "moe_tkd": P(dp, None),
        }
        rules = {k: NamedSharding(self.mesh, v) for k, v in specs.items()}
        # expert-parallel context: MoE layers shard_map over the model axis
        # (manual EP) when it exists; see models.moe.moe_ep.
        if self.ep and self.tp == "model" and "model" in self.mesh.axis_names:
            rules["__ep__"] = (self.mesh, "model")
        return rules


# ---------------------------------------------------------------------------
# Tensor-parallel paged serving (continuous batching over the mesh)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedServePlan:
    """Partitioning of the continuous-batching serve path over a mesh.

    The paged decode / prefill-chunk step runs inside ONE manual
    ``shard_map`` over the mesh's ``model`` axis (the paper's CU ring):

      * attention + MLP weights Megatron column-shard over ``axis``
        (``param_specs``); each block closes its pair at the
        ``tp_row_dot``/``tp_psum`` marks in ``models.model`` (no-ops
        off-mesh).  ``reduce="gather"`` (CPU/test default) all-gathers the
        column intermediate and keeps row weights replicated — every
        activation bit-identical to single-device, the mode the
        byte-identical invariant is asserted under; ``reduce="psum"``
        (accelerator default) row-shards the closing weight and spends ONE
        f32 psum per block — minimal bytes, equal up to f32 reassociation;
      * page pools shard per the owning backend's ``paged_partition_spec``
        (GQA: KV-head axis — per-device KV bytes/token shrink 1/TP; MLA:
        latent pools replicate, heads shard) while the logical page-id
        space, page tables, positions, and ``SlotSampling`` tensors stay
        replicated — the host-side allocator is sharding-agnostic;
      * embeddings / head / norms / MoE experts replicate: decode logits
        are tiny next to the KV stream, and expert-sharded MoE would need
        nested shard_map (the EP path) inside the manual region.  Follow-on
        work, recorded in ROADMAP.md.

    Everything the engine batches per-iteration (tokens, pos, page table,
    sampling tensors) is data with replicated specs, so the sharded step
    keeps the single-device invariant: one compiled signature per mesh
    shape, any request mix.
    """

    mesh: Mesh
    axis: str = "model"
    reduce: str = "gather"         # "gather" (bit-exact) | "psum" (Megatron)
    # KV-head replication factor (tp // n_kv_heads) for llama3-style GQA
    # models with fewer KV heads than the TP degree: each KV head is
    # materialized on ``kv_repl`` consecutive shards (1 local head per
    # shard), so q heads still shard tp-way while every shard streams
    # exactly the KV head its q-group reads.  1 = plain head sharding.
    kv_repl: int = 1

    @property
    def tp(self) -> int:
        return int(self.mesh.shape[self.axis])

    # ---------------- local (per-shard) model geometry ----------------
    def local_config(self, cfg: ModelConfig) -> ModelConfig:
        """The per-shard config the manual region's model code runs with:
        head counts and the dense-MLP width divide by TP (columns are
        sliced in contiguous head/d_ff blocks); everything replicated
        (d_model, vocab, MoE experts, latent ranks) keeps its full size.
        Under KV-head replication each shard holds exactly ONE KV head
        (its q-group's), so the local model is plain GQA with group size
        ``n_heads // tp``."""
        if self.tp == 1:
            return cfg
        if self.kv_repl > 1:
            kvh = 1
        elif cfg.n_kv_heads % self.tp == 0:
            kvh = cfg.n_kv_heads // self.tp
        else:
            kvh = cfg.n_kv_heads
        return dataclasses.replace(
            cfg, n_heads=cfg.n_heads // self.tp, n_kv_heads=kvh,
            d_ff=cfg.d_ff // self.tp)

    def pool_config(self, cfg: ModelConfig) -> ModelConfig:
        """The config the GLOBAL page pools are built with: under KV-head
        replication the pool's KV-head axis is physically widened to
        ``n_kv_heads * kv_repl`` (= tp) heads so the even tp-way shard of
        that axis hands each shard its one replicated head.  Identity
        otherwise."""
        if self.kv_repl == 1:
            return cfg
        return dataclasses.replace(cfg,
                                   n_kv_heads=cfg.n_kv_heads * self.kv_repl)

    def prepare_params(self, params, cfg: ModelConfig):
        """Physically replicate the KV projections for an uneven
        ``n_kv_heads < tp`` deployment: each KV head's ``wk``/``wv``
        columns (and ``bk``/``bv`` entries) are repeated ``kv_repl`` times
        along the head axis, after which the normal contiguous column
        shard gives shard ``d`` the exact single head its local q heads
        attend to (shard d's q heads are global heads
        ``[d*H/tp, (d+1)*H/tp)``, all inside KV group ``d // kv_repl``).
        Bit-exact: every shard's k/v equals the single-device values for
        that head.  Identity when ``kv_repl == 1``."""
        if self.kv_repl == 1:
            return params
        r, kvh, hd = self.kv_repl, cfg.n_kv_heads, cfg.hd

        def expand(path, leaf):
            names = _path_names(path)
            if any(n in ("moe", "ssm") for n in names):
                return leaf
            name = names[-1]
            if name in ("wk", "wv"):
                *lead, d, _ = leaf.shape
                x = leaf.reshape(*lead, d, kvh, hd)
                return jnp.repeat(x, r, axis=-2).reshape(*lead, d,
                                                         kvh * r * hd)
            if name in ("bk", "bv"):
                *lead, _ = leaf.shape
                x = leaf.reshape(*lead, kvh, hd)
                return jnp.repeat(x, r, axis=-2).reshape(*lead, kvh * r * hd)
            return leaf

        return jax.tree_util.tree_map_with_path(expand, params)

    # ---------------- parameters ----------------
    def _serve_param_spec(self, names: list[str], ndim: int) -> P:
        name = names[-1]
        in_moe = any(n == "moe" for n in names)
        in_ssm = any(n == "ssm" for n in names)
        if in_moe or in_ssm:
            return P()          # replicated (computed fully on every shard)
        if name in _BIAS_COL:
            return P(*([None] * (ndim - 1)), self.axis)
        if name in _COL_SHARD and name not in ("head", "in_proj"):
            if ndim >= 2:
                return P(*([None] * (ndim - 2)), None, self.axis)
            return P(*([None] * (ndim - 1)), self.axis)
        if name in _ROW_SHARD:
            if self.reduce == "gather":
                return P()      # closing matmul runs replicated, bit-exact
            return P(*([None] * (ndim - 2)), self.axis, None)
        return P()              # embed / head / norms: replicated

    def param_specs(self, params) -> Any:
        """PartitionSpec pytree for the manual region's in_specs."""
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self._serve_param_spec(_path_names(path),
                                                      leaf.ndim),
            params)

    def param_shardings(self, params) -> Any:
        return jax.tree.map(lambda spec: NamedSharding(self.mesh, spec),
                            self.param_specs(params),
                            is_leaf=lambda s: isinstance(s, P))

    # ---------------- page pools ----------------
    def pool_specs(self, model, cache_dtype=None) -> list:
        """PartitionSpec pytree matching ``Model.init_paged_cache``'s
        structure (list over segments, tuple over kinds, dict leaves —
        stacked along a leading reps axis for scanned segments).

        ``cache_dtype`` must match the engine's pool dtype: quantized
        ("fp8"/"int8") pools carry extra ``k_scale``/``v_scale`` metadata
        leaves (one fewer dim than the code leaves) that shard the same
        KV-head axis, so the spec tree is probed from an actual tiny pool
        rather than the declared token-leaf keys."""
        from repro.models.attention_backends import backend_for_kind

        specs = []
        for seg in model.plan:
            kinds_specs = []
            for kind in seg.kinds:
                be = backend_for_kind(kind)
                part = (be.paged_partition_spec or {}) if be else {}
                probe = (be.init_page_pool(model.cfg, 2, 1,
                                           dtype=cache_dtype or jnp.bfloat16)
                         if be and be.supports_paged else {})
                leaf_specs = {}
                for key, leaf in probe.items():
                    dim = part.get(key)
                    lead = 0 if seg.reps == 1 else 1
                    if dim is None or self.tp == 1:
                        leaf_specs[key] = P()
                    else:
                        spec = [None] * (lead + leaf.ndim)
                        spec[lead + dim] = self.axis
                        leaf_specs[key] = P(*spec)
                kinds_specs.append(leaf_specs)
            specs.append(tuple(kinds_specs))
        return specs

    def pool_shardings(self, model, cache_dtype=None) -> list:
        return jax.tree.map(lambda spec: NamedSharding(self.mesh, spec),
                            self.pool_specs(model, cache_dtype=cache_dtype),
                            is_leaf=lambda s: isinstance(s, P))

    # ---------------- accounting ----------------
    def psum_bytes_per_step(self, model, num_slots: int,
                            dtype_bytes: int = 4) -> int:
        """Per-device bytes a decode step moves through its TP collectives,
        summed over the attention + dense-MLP reduction of every layer.
        ``"psum"``: ring all-reduce of the (slots, d_model) partial —
        2(tp-1)/tp of the payload.  ``"gather"``: all-gather of the
        column-sharded intermediate — (tp-1)/tp of its (wider) payload."""
        if self.tp == 1:
            return 0
        cfg = model.cfg
        n = self.tp
        total = 0.0
        for seg in model.plan:
            for kind in seg.kinds:
                if self.reduce == "psum":
                    att = mlp = 2 * (n - 1) / n * num_slots * cfg.d_model
                else:
                    width = (cfg.n_heads * (cfg.v_hd if kind.startswith("mla")
                                            else cfg.hd))
                    att = (n - 1) / n * num_slots * width
                    mlp = (n - 1) / n * num_slots * cfg.d_ff
                total += att * seg.reps
                if not kind.endswith("_moe"):
                    total += mlp * seg.reps
        return int(total * dtype_bytes)


def paged_kv_token_bytes(model, *, tp: int = 1, dtype_bytes: int = 4,
                         kv_repl: int = 1, cache_dtype=None) -> int:
    """Per-device pool bytes one cached token costs — the strong-scaling
    observable: leaves sharded by their backend's ``paged_partition_spec``
    divide by ``tp``, replicated leaves don't.  Under KV-head replication
    the sharded leaves are first widened by ``kv_repl`` (each KV head is
    materialized on ``kv_repl`` shards), so per-device bytes bottom out at
    one head instead of continuing to shrink 1/TP.

    With ``cache_dtype`` set the bytes are measured from an actual tiny
    pool built at that dtype (``dtype_bytes`` is ignored): quantized
    fp8/int8 pools then report the *packed* bytes — 1-byte codes plus the
    f32 per-token scale leaves — so the deployment budget equals what the
    engine allocates."""
    full, ring = paged_kv_token_bytes_split(model, tp=tp,
                                            dtype_bytes=dtype_bytes,
                                            kv_repl=kv_repl,
                                            cache_dtype=cache_dtype)
    return full + ring


def paged_kv_token_bytes_split(model, *, tp: int = 1, dtype_bytes: int = 4,
                               kv_repl: int = 1,
                               cache_dtype=None) -> tuple[int, int]:
    """``paged_kv_token_bytes`` split into its ``(full, ring)`` residency
    halves: bytes/token in full-context segments vs sliding-window
    segments.  Windowed layers hold O(window) tokens per slot (the ring
    space reclaims pages behind the window — ``runtime.state_cache``)
    while full layers hold O(context), so deployment budgeting prices the
    two classes differently.  SSM segments write no token-indexed pages
    and contribute to neither half (their per-SLOT state is priced by
    ``state_cache.state_bytes_per_slot``)."""
    from repro.models.attention_backends import backend_for_kind

    full = ring = 0
    for seg in model.plan:
        seg_total = 0
        for kind in seg.kinds:
            be = backend_for_kind(kind)
            if be is None or not be.supports_paged:
                continue
            part = be.paged_partition_spec or {}
            if cache_dtype is not None:
                pool = be.init_page_pool(model.cfg, 2, 1, dtype=cache_dtype)
                leaf_bytes = {k: int(np.prod(v.shape[2:])) * v.dtype.itemsize
                              for k, v in pool.items()}
            else:
                pool = be.init_page_pool(model.cfg, 2, 1)
                leaf_bytes = {k: int(np.prod(v.shape[2:])) * dtype_bytes
                              for k, v in pool.items()}
            for key, per_tok in leaf_bytes.items():
                if tp > 1 and part.get(key) is not None:
                    per_tok = per_tok * kv_repl // tp
                seg_total += per_tok * seg.reps
        if seg.window is not None:
            ring += seg_total
        else:
            full += seg_total
    return full, ring


def make_paged_serve_plan(cfg: ModelConfig, mesh: Mesh,
                          axis: str = "model",
                          reduce: str = "auto") -> PagedServePlan:
    """Validate and build the TP partitioning of the paged serve path.

    ``reduce="auto"``: the bit-exact ``"gather"`` composition on CPU
    (where byte-identity to single-device is the test contract), the
    one-psum-per-block ``"psum"`` Megatron pairing on accelerators."""
    if reduce == "auto":
        from repro.kernels import on_cpu
        reduce = "gather" if on_cpu() else "psum"
    if reduce not in ("gather", "psum"):
        raise ValueError(f"reduce={reduce!r} (want 'auto'/'gather'/'psum')")
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {axis!r} axis")
    tp = int(mesh.shape[axis])
    if tp == 1:
        return PagedServePlan(mesh=mesh, axis=axis, reduce=reduce)
    if cfg.family in ("ssm", "hybrid") or cfg.ssm:
        raise NotImplementedError(
            "sharded paged serving needs a paged state pool for SSM/hybrid "
            "families first (see ROADMAP)")
    kv_repl = 1
    problems = []
    if cfg.n_heads % tp:
        problems.append(f"n_heads={cfg.n_heads}")
    if not cfg.mla and cfg.n_kv_heads % tp:
        if tp % cfg.n_kv_heads == 0:
            # llama3-style kvh < tp: replicate each KV head on tp/kvh
            # consecutive shards (one local head each); see prepare_params
            kv_repl = tp // cfg.n_kv_heads
        else:
            problems.append(f"n_kv_heads={cfg.n_kv_heads}")
    if cfg.d_ff % tp:
        problems.append(f"d_ff={cfg.d_ff}")
    if problems:
        raise ValueError(
            f"{cfg.name}: {', '.join(problems)} not divisible by the "
            f"{tp}-way {axis!r} axis; pick a mesh whose TP degree divides "
            "the head/FFN widths (KV heads may also be an integer divisor "
            "of TP — they replicate)")
    return PagedServePlan(mesh=mesh, axis=axis, reduce=reduce,
                          kv_repl=kv_repl)


def split_mesh(mesh: Mesh, n_first: int, n_second: int | None = None,
               axis: str = "model") -> tuple[Mesh, Mesh]:
    """Split ``mesh`` into two disjoint submeshes along ``axis`` — the
    phase slices of a disaggregated deployment (prefill gets the first
    ``n_first`` positions, decode the next ``n_second``, default the
    rest).  Each submesh keeps every other axis intact, so the two phase
    engines can build independent serve plans with DIFFERENT TP degrees
    over the same pod of devices."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {axis!r} axis")
    ai = mesh.axis_names.index(axis)
    size = mesh.devices.shape[ai]
    if n_second is None:
        n_second = size - n_first
    if n_first < 1 or n_second < 1 or n_first + n_second > size:
        raise ValueError(
            f"cannot split a {size}-way {axis!r} axis into "
            f"{n_first}+{n_second} device slices")
    sl = [slice(None)] * mesh.devices.ndim
    sl[ai] = slice(0, n_first)
    first = mesh.devices[tuple(sl)]
    sl[ai] = slice(n_first, n_first + n_second)
    second = mesh.devices[tuple(sl)]
    # type(mesh), not Mesh: keeps duck-typed mesh stand-ins (tests, dry
    # runs on a single host device) flowing through unchanged
    cls = type(mesh)
    return cls(first, mesh.axis_names), cls(second, mesh.axis_names)


def _as_tuple(x) -> tuple:
    return x if isinstance(x, tuple) else (x,)


def _full_tp_ok(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Dense archs whose projection widths divide the WHOLE mesh can run
    decode fully tensor-parallel (MoE/SSM/hybrid keep the DP plan: expert
    counts / head layouts don't span 256-512 shards)."""
    if cfg.moe or cfg.ssm or cfg.family in ("ssm", "hybrid") or cfg.mla:
        return False
    total = 1
    for a in mesh.axis_names:
        total *= mesh.shape[a]
    dims = (cfg.n_heads * cfg.hd, cfg.n_kv_heads * cfg.hd, cfg.d_ff,
            cfg.padded_vocab)
    return all(d % total == 0 for d in dims)


def make_plan(cfg: ModelConfig, mesh: Mesh, *, global_batch: int,
              shape_kind: str,
              param_bytes: float | None = None) -> ParallelPlan:
    """Choose the plan for an (arch x shape x mesh) cell.

    ``shape_kind``: train | prefill | decode | long_decode.
    ``param_bytes``: total bf16 weight bytes (for the FSDP fit decision);
    computed from the footprint when omitted.
    """
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    model_size = mesh.shape.get("model", 1)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    if param_bytes is None:
        from repro.models.footprint import compute_footprint
        param_bytes = compute_footprint(cfg).total_params * 2.0

    needs_fsdp = param_bytes / max(model_size, 1) > _WEIGHT_FIT_BYTES

    if shape_kind == "train":
        # FSDP always on for training: params + AdamW state shard over DP.
        # ep=False: MoE training uses the GSPMD-hinted capacity path.
        return ParallelPlan(mesh, dp=dp_axes, tp="model", fsdp=dp_axes,
                            cache_seq=None, seq_parallel=True, ep=False)

    if shape_kind == "long_decode" or global_batch < dp_size:
        # batch unshardable: the KV$/state context shards over EVERY mesh
        # axis (the paper's "scale bandwidth by adding CUs to the ring" —
        # at 500k tokens the context stream IS the memory roofline term);
        # weights keep model-axis TP (KV-projection widths of the small
        # sub-quadratic archs don't divide a 512-way ring).
        all_axes: tuple[str, ...] = tuple(axes)
        return ParallelPlan(mesh, dp=(), tp="model", cache_seq=all_axes)

    if shape_kind == "decode" and _full_tp_ok(cfg, mesh):
        # The paper's Contribution-2 regime for dense decode: weights
        # column-shard across EVERY chip, so the whole batch shares ONE
        # weight stream (vs one stream per DP replica — 16x the weight
        # traffic at dp=16); the KV$ context shards over the same ring
        # and activations pay small per-layer all-reduces.
        all_axes = tuple(axes)
        return ParallelPlan(mesh, dp=(), tp=all_axes, cache_seq=all_axes)

    fsdp = dp_axes if needs_fsdp else ()
    cache_seq = "model" if shape_kind in ("decode", "prefill") else None
    return ParallelPlan(mesh, dp=dp_axes, tp="model", fsdp=fsdp,
                        cache_seq=cache_seq)
