"""Pure-jnp oracle for flash-decode GQA attention."""
from repro.models.common import decode_attention_ref  # noqa: F401
